//! Batching inference server: the L3 request path over quantized weights.
//!
//! Architecture (vLLM-router-style, scaled to this repo): callers submit
//! [`Request`]s to a [`Server`] handle; a batcher thread maps requests
//! onto a fixed pool of KV-cache lanes (`eval_batch` of them by default;
//! with a [`ServeConfig::kv_budget_bytes`] the pool is sized
//! `budget / bytes_per_lane`, and [`ServeConfig::kv`] can store lanes as
//! RaBitQ codes so the same RAM holds several times the lanes — see
//! [`crate::kvq`]). Each newly
//! admitted request is **prefilled** once — its prompt runs through the
//! model a single time, depositing per-layer K/V rows into its lane of a
//! [`KvCache`] — and from then on rides fixed-shape **batched decode
//! steps**: one token per active lane per step, attending over cached
//! K/V instead of recomputing the window. Per-token cost is therefore
//! O(context) attention + O(1) linear work, not a full O(context)
//! forward; `benches/kernels.rs` records the resulting tokens/s win as
//! `serve_kv` vs `serve_recompute`.
//!
//! When a lane's window fills (context = `seq_len`), the batcher slides
//! it by re-prefilling the last `seq_len` tokens — the model's absolute
//! position embeddings re-position every token on a slide, so the cached
//! rows are genuinely stale and recompute is the correct (and reference-
//! exact) behavior. Python is never on this path; with packed weights
//! attached the decode linears run on RaBitQ codes via `qgemm`, whose
//! parallelism comes from the process-wide persistent worker pool
//! ([`crate::threadpool::global`]) — the batcher thread submits jobs and
//! participates in them itself, so even a shut-down pool drains requests
//! to completion (`rust/tests/pool_drain.rs`).
//!
//! Front-end hooks (what the HTTP layer in [`crate::net`] builds on):
//! [`Server::submit_streaming`] delivers tokens one [`StreamEvent`] at a
//! time; every request carries a [`CancelToken`] the batcher polls each
//! round, so an abandoned request frees its KV lane mid-flight; a bounded
//! admission queue ([`ServeConfig::max_queue`]) fails fast with
//! [`AdmitError::QueueFull`] instead of queueing without limit; and a
//! live [`ServerStats`] snapshot ([`Server::stats`]) answers while
//! generation is in flight (including the admission-queue depth, so
//! generate and index load read from one endpoint).
//!
//! A second workload lives beside the batcher: [`index::IndexServer`]
//! serves the retrieval subsystem ([`crate::index`]) — embed, add,
//! query — directly on the HTTP workers' threads (see its module docs
//! for why it needs no batcher).

pub mod index;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::Result;

use crate::kvq::{self, KvSensitivity, KvqError, KvqPlan, KvqPolicy};
use crate::model::{Manifest, ModelParams};
use crate::obs::{self, trace};
use crate::runtime::{KvCache, ModelRuntime, NativeModel, PackedLayers};
use crate::util::percentile;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Greedy if 0.0, else temperature sampling with this temperature.
    pub temperature: f32,
    pub seed: u64,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_secs: f64,
    /// Number of generation steps (one sampled token each: the prefill
    /// yields the first, every decode step or window slide one more).
    pub steps: usize,
}

/// Per-token event delivered on a [`Server::submit_streaming`] channel.
///
/// The stream is a sequence of `Token` events (one per sampled token, in
/// order) terminated by exactly one `Done` carrying the full
/// [`Completion`]. If the request is cancelled or the batcher dies, the
/// sender is dropped instead and the receiver disconnects without a
/// `Done` — consumers must treat a disconnect as "generation aborted".
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// One sampled token: `index` is its 0-based position in the output.
    Token {
        /// Request id (as returned by `submit_streaming`).
        id: u64,
        /// 0-based index of this token within the generation.
        index: usize,
        /// The sampled token.
        token: i32,
    },
    /// Terminal event: the finished generation.
    Done(Completion),
}

/// Cooperative cancellation handle for an in-flight request.
///
/// Cancelling is asynchronous: the batcher checks the flag once per
/// round, frees the request's KV lane, and drops its event sender (so
/// stream receivers disconnect). Cancelling an already-finished request
/// is a harmless no-op. Clones share the same flag.
#[derive(Clone, Debug)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    fn new() -> Self {
        CancelToken(Arc::new(AtomicBool::new(false)))
    }

    /// Request cancellation (idempotent).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// True once [`CancelToken::cancel`] has been called.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// Why [`Server::submit`] / [`Server::submit_streaming`] refused a request.
///
/// A typed error (rather than an opaque `anyhow::Error`) so front-ends can
/// map each case to the right transport response — the HTTP layer turns
/// `QueueFull` into 429, `NotAccepting` into 503 and `InvalidRequest`
/// into 400.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The bounded admission queue is at capacity (backpressure: retry
    /// later rather than queueing unboundedly).
    QueueFull,
    /// The server stopped accepting work: shutdown began or the batcher
    /// thread exited (e.g. its runtime factory failed).
    NotAccepting,
    /// The request can never be served (e.g. a prompt token outside the
    /// model's vocabulary); admitting it would poison the batcher.
    InvalidRequest(String),
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::QueueFull => write!(f, "admission queue full"),
            AdmitError::NotAccepting => {
                write!(f, "server is not accepting requests (shut down or batcher exited)")
            }
            AdmitError::InvalidRequest(why) => write!(f, "invalid request: {why}"),
        }
    }
}

impl std::error::Error for AdmitError {}

impl From<AdmitError> for anyhow::Error {
    fn from(e: AdmitError) -> anyhow::Error {
        anyhow::Error::msg(e.to_string())
    }
}

/// Handle for a streaming submission: the request id, the per-token event
/// receiver, and the cancellation token.
pub struct StreamHandle {
    /// Request id.
    pub id: u64,
    /// Per-token event channel (see [`StreamEvent`] for the protocol).
    pub events: mpsc::Receiver<StreamEvent>,
    /// Cancellation handle (clone freely; see [`CancelToken`]).
    pub cancel: CancelToken,
}

/// Server construction options.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Admission-queue capacity; `0` means unbounded. When bounded, a
    /// submit against a full queue fails fast with
    /// [`AdmitError::QueueFull`] instead of queueing — the backpressure
    /// signal the HTTP front-end surfaces as 429.
    pub max_queue: usize,
    /// KV-cache storage policy for the lane pool: dense f32 (default),
    /// uniform N-bit RaBitQ codes, or a per-layer AllocateBits plan
    /// solved under the budget (see [`crate::kvq::KvqPolicy`]).
    pub kv: KvqPolicy,
    /// Total KV memory budget in bytes across the whole lane pool; `0`
    /// means "no budget" (the pool stays `eval_batch` lanes wide). With a
    /// budget, the lane count becomes `budget / bytes_per_lane` — the
    /// memory→lanes conversion that makes 4-bit KV serve more concurrent
    /// requests than f32 from the same RAM. A budget too small for even
    /// one lane is a typed **construction** error
    /// ([`KvqError::BudgetTooSmall`]), never a runtime death.
    pub kv_budget_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_queue: 0, kv: KvqPolicy::DenseF32, kv_budget_bytes: 0 }
    }
}

/// Hard ceiling on lanes derived from a KV byte budget: past this, decode
/// batches get so wide that per-step latency (not memory) dominates, and a
/// generous budget should not silently produce a pathological pool.
pub const MAX_KV_LANES: usize = 256;

/// The fully-resolved KV lane-pool configuration: bit plan (None = dense
/// f32), lane count, and the per-lane footprint both were derived from.
/// Produced by config validation at `Server` construction (or inside the
/// batcher for factory-made runtimes) and reported through
/// [`ServerStats`].
#[derive(Clone, Debug)]
struct ResolvedKv {
    plan: Option<KvqPlan>,
    lanes: usize,
    bytes_per_lane: usize,
    kv_bits: f64,
}

/// Deterministic calibration prompt for KV sensitivity estimation.
fn kv_calibration_sample(seq_len: usize, vocab: usize) -> Vec<i32> {
    (0..seq_len.min(32)).map(|i| ((i * 7 + 1) % vocab) as i32).collect()
}

/// Measure per-layer KV sensitivities when the policy needs them
/// ([`KvqPolicy::Budget`]): one short prefill over a deterministic sample.
fn kv_sensitivity_if_needed(
    cfg: &ServeConfig,
    model: &NativeModel,
    manifest: &Manifest,
    params: &ModelParams,
    packed: Option<&PackedLayers>,
) -> Result<Option<KvSensitivity>> {
    if !matches!(cfg.kv, KvqPolicy::Budget { .. }) {
        return Ok(None);
    }
    let sample = kv_calibration_sample(model.seq_len, model.vocab);
    Ok(Some(kvq::estimate_kv_sensitivity(model, manifest, params, packed, &sample, 0)?))
}

/// Validate + resolve the KV config against a model: bit plan, per-lane
/// bytes, lane count. All failure modes are typed [`KvqError`]s — this is
/// the config-validation surface `Server::start_native_packed_with` runs
/// **before** spawning anything.
fn resolve_kv(
    cfg: &ServeConfig,
    model: &NativeModel,
    eval_batch: usize,
    sens: Option<&KvSensitivity>,
) -> Result<ResolvedKv, KvqError> {
    // Budget policy: each of the eval_batch "baseline" lanes gets an equal
    // share of the total budget as its per-lane cap; the actual lane count
    // is then recomputed from what the solved plan really costs. When the
    // equal share is too aggressive (the total still fits >= 1 lane, just
    // fewer than eval_batch), fall back to the cheapest admissible lane
    // size — BudgetTooSmall is reserved for budgets that truly cannot fit
    // one lane, and always reports the user's configured total.
    let lane_budget = if cfg.kv_budget_bytes > 0 {
        Some((cfg.kv_budget_bytes / eval_batch.max(1)).max(1))
    } else {
        None
    };
    let solve = |lane_budget: Option<usize>| {
        cfg.kv.plan(
            model.n_layers,
            model.seq_len,
            model.d_model,
            model.n_heads,
            lane_budget,
            sens,
        )
    };
    let plan = match solve(lane_budget) {
        Ok(p) => p,
        Err(KvqError::BudgetTooSmall { min_lane_bytes, .. })
            if cfg.kv_budget_bytes >= min_lane_bytes =>
        {
            solve(Some(min_lane_bytes))?
        }
        Err(KvqError::BudgetTooSmall { min_lane_bytes, .. }) => {
            return Err(KvqError::BudgetTooSmall {
                budget_bytes: cfg.kv_budget_bytes,
                min_lane_bytes,
            });
        }
        Err(e) => return Err(e),
    };
    let bytes_per_lane = match &plan {
        Some(p) => p.bytes_per_lane(model.seq_len, model.d_model, model.n_heads),
        None => kvq::dense_bytes_per_lane(model.n_layers, model.seq_len, model.d_model),
    };
    let lanes = if cfg.kv_budget_bytes == 0 {
        eval_batch
    } else {
        let n = cfg.kv_budget_bytes / bytes_per_lane;
        if n == 0 {
            return Err(KvqError::BudgetTooSmall {
                budget_bytes: cfg.kv_budget_bytes,
                min_lane_bytes: bytes_per_lane,
            });
        }
        n.min(MAX_KV_LANES)
    };
    let kv_bits = plan.as_ref().map(|p| p.avg_bits()).unwrap_or(32.0);
    Ok(ResolvedKv { plan, lanes, bytes_per_lane, kv_bits })
}

/// Where a request's results go: a single completion channel
/// ([`Server::submit`]) or a per-token event channel
/// ([`Server::submit_streaming`]).
enum Sink {
    Complete(mpsc::Sender<Completion>),
    Stream(mpsc::Sender<StreamEvent>),
}

impl Sink {
    /// Deliver one sampled token. Returns false when the receiver is gone
    /// (streaming consumer dropped the channel) — the batcher treats that
    /// exactly like a cancellation and frees the lane.
    fn token(&self, id: u64, index: usize, token: i32) -> bool {
        match self {
            Sink::Complete(_) => true,
            Sink::Stream(tx) => tx.send(StreamEvent::Token { id, index, token }).is_ok(),
        }
    }

    fn done(&self, c: Completion) {
        match self {
            Sink::Complete(tx) => {
                let _ = tx.send(c);
            }
            Sink::Stream(tx) => {
                let _ = tx.send(StreamEvent::Done(c));
            }
        }
    }
}

struct Active {
    req: Request,
    generated: Vec<i32>,
    submitted: Instant,
    steps: usize,
    cancel: CancelToken,
    sink: Sink,
    /// Request id for tracing: adopted from the submitting thread's
    /// ambient id (the HTTP layer installs one per connection) or minted
    /// at submit, so batcher-side spans always land under the same id
    /// the client sees in its `X-Request-Id` echo.
    rid: Arc<str>,
    /// Tracer-clock reading at admission; the batcher turns it into the
    /// `queue_wait` span when the request lands on a KV lane.
    enqueued_us: u64,
}

struct Shared {
    queue: Mutex<VecDeque<Active>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    /// Set by the batcher thread on exit (normal or error), *before* it
    /// drains the queue — [`Server::submit`] checks it under the queue
    /// lock so no request can be stranded behind a dead batcher.
    dead: AtomicBool,
    /// Admission-queue capacity (0 = unbounded), from [`ServeConfig`].
    max_queue: usize,
    /// Model vocabulary size, published by the batcher once its runtime
    /// is up (0 = not yet known). Lets `submit` reject out-of-vocab
    /// prompts with a typed error before they reach the model.
    vocab: AtomicUsize,
    /// Live stats snapshot, refreshed by the batcher once per round so
    /// `/v1/stats` can answer while generation is in flight.
    live: Mutex<ServerStats>,
    /// Test hook ([`Server::inject_batcher_panic`]): when set, the
    /// batcher panics at the top of its next scheduling round, which is
    /// how the panic-containment regression tests simulate a bug in
    /// model code without depending on one.
    panic_inject: AtomicBool,
}

/// Read a mutex even when the batcher thread poisoned it by panicking
/// mid-round: every value behind these locks (queue, flags, stats
/// snapshot) is valid at any intermediate state, and refusing to read
/// one would turn a contained batcher death into a panic in the HTTP
/// worker that happened to probe `/v1/stats` next.
fn unpoison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Server handle.
///
/// # Lifecycle
///
/// 1. [`Server::start`] / [`Server::start_native_packed`] spawn the
///    batcher thread, which owns the runtime, the weights, and one
///    [`KvCache`] with `eval_batch` request lanes.
/// 2. [`Server::submit`] enqueues work while the batcher is alive. Once
///    shutdown has begun, or the batcher has exited (failed runtime
///    factory, forward error), `submit` returns an error instead of
///    queueing into a dead thread.
/// 3. [`Server::shutdown`] waits for in-flight **and** queued requests to
///    finish, joins the batcher, and returns its [`ServerStats`] (or its
///    error). Dropping the handle performs the same drain-and-join but
///    discards the result.
///
/// If the batcher dies early, receivers for already-queued requests
/// disconnect (`recv` returns `Err`) rather than blocking forever: the
/// exiting thread marks itself dead and then drains the queue.
pub struct Server {
    shared: Arc<Shared>,
    worker: Option<thread::JoinHandle<Result<ServerStats>>>,
    next_id: Mutex<u64>,
}

/// Aggregate metrics reported on shutdown.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub completions: usize,
    /// Model executions: prefills (admissions + window slides) plus
    /// batched decode steps.
    pub batch_steps: usize,
    /// Sequence rows processed across all executions (a prefill is one
    /// row, a batched decode is one row per active lane).
    pub total_rows: usize,
    pub tokens_generated: usize,
    /// Prompt tokens pushed through prefill (admissions + slides).
    pub prefill_tokens: usize,
    /// Batched decode executions (the KV fast path).
    pub decode_steps: usize,
    /// Full-window re-prefills (context outgrew `seq_len`).
    pub window_slides: usize,
    /// Requests abandoned mid-flight: an explicit [`CancelToken::cancel`],
    /// a dropped stream receiver, or a prompt the model rejected at
    /// admission. Each freed its KV lane without producing a completion.
    pub cancelled: usize,
    pub latencies: Vec<f64>,
    pub wall_secs: f64,
    /// Mean stored bits per cached KV element (32 = dense f32 rows,
    /// lower = RaBitQ-compressed cache; see [`crate::kvq`]).
    pub kv_bits: f64,
    /// Per-lane KV footprint in bytes (what a memory budget divides by).
    pub kv_bytes_per_lane: usize,
    /// KV lane-pool width (max concurrently-decoding requests).
    pub lanes: usize,
    /// Lanes currently holding an active request (live snapshot only).
    pub lanes_active: usize,
    /// Requests admitted but not yet mapped onto a KV lane, at snapshot
    /// time — republished per batcher round so generate and index load
    /// are observable from one `/v1/stats` read (live snapshot only; the
    /// shutdown stats report 0, the queue having drained).
    pub queue_depth: usize,
}

impl ServerStats {
    pub fn mean_batch_occupancy(&self, batch: usize) -> f64 {
        if self.batch_steps == 0 {
            return 0.0;
        }
        self.total_rows as f64 / (self.batch_steps * batch) as f64
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_secs
    }

    pub fn p50_latency(&self) -> f64 {
        percentile(&self.latencies, 50.0)
    }

    pub fn p95_latency(&self) -> f64 {
        percentile(&self.latencies, 95.0)
    }
}

fn softmax_sample(logits: &[f32], temperature: f32, seed: u64, step: usize) -> i32 {
    if temperature <= 0.0 {
        return crate::util::argmax(logits) as i32;
    }
    let maxl = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    // Degenerate logit rows (all -inf, or any NaN contaminating the max)
    // have no softmax: fall back to greedy instead of building a NaN
    // cumulative table that would panic inside `sample_cumulative`.
    if !maxl.is_finite() {
        return crate::util::argmax(logits) as i32;
    }
    let mut rng = crate::rng::Rng::new(seed ^ (step as u64).wrapping_mul(0x9E37));
    let exps: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - maxl) / temperature) as f64).exp())
        .collect();
    let mut cum = Vec::with_capacity(exps.len());
    let mut acc = 0.0;
    for e in exps {
        acc += e;
        cum.push(acc);
    }
    // acc >= exp(0) = 1 for the max logit, so the table is well-formed
    // whenever maxl is finite; guard anyway against NaN stragglers.
    if !acc.is_finite() || acc <= 0.0 {
        return crate::util::argmax(logits) as i32;
    }
    rng.sample_cumulative(&cum) as i32
}

impl Server {
    /// Start a server over `params` (typically quantized weights).
    ///
    /// PJRT handles are not `Send`, so the batcher thread constructs its
    /// own runtime via `factory` (e.g. `|| ModelRuntime::load(...)` with a
    /// fresh `Runtime::cpu()`); `params` moves into the thread. The lane
    /// pool is `eval_batch` wide and each lane's KV window is the model's
    /// `seq_len`.
    pub fn start<F>(factory: F, params: ModelParams) -> Server
    where
        F: FnOnce() -> Result<ModelRuntime> + Send + 'static,
    {
        Server::start_with(factory, params, ServeConfig::default())
    }

    /// [`Server::start`] with explicit [`ServeConfig`] (bounded admission
    /// queue, KV storage policy, …).
    ///
    /// The factory path cannot validate the KV config eagerly (the model
    /// shape only exists once the factory has run inside the batcher
    /// thread), so a bad KV config surfaces as a dead batcher whose error
    /// [`Server::shutdown`] returns. Prefer
    /// [`Server::start_native_packed_with`], which validates at
    /// construction and returns a typed error instead.
    pub fn start_with<F>(factory: F, params: ModelParams, cfg: ServeConfig) -> Server
    where
        F: FnOnce() -> Result<ModelRuntime> + Send + 'static,
    {
        Server::start_impl(factory, params, cfg, None)
    }

    fn start_impl<F>(
        factory: F,
        params: ModelParams,
        cfg: ServeConfig,
        resolved: Option<ResolvedKv>,
    ) -> Server
    where
        F: FnOnce() -> Result<ModelRuntime> + Send + 'static,
    {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            dead: AtomicBool::new(false),
            max_queue: cfg.max_queue,
            vocab: AtomicUsize::new(0),
            live: Mutex::new(ServerStats::default()),
            panic_inject: AtomicBool::new(false),
        });
        let s2 = Arc::clone(&shared);
        let worker = thread::spawn(move || {
            // A panicking batcher round (a bug in model code, or the test
            // hook) must not skip the dead-marking below — that would
            // strand every queued submitter on a receiver that never
            // disconnects. Contain the unwind here: in-flight requests
            // drop their sinks as the loop's locals unwind (receivers
            // disconnect -> the HTTP layer answers a typed 500), and the
            // panic becomes the error `Server::shutdown` reports.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                match factory() {
                    Ok(mrt) => batcher_loop(&s2, mrt, params, &cfg, resolved),
                    Err(e) => Err(e),
                }
            }))
            .unwrap_or_else(|payload| {
                let what = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic payload".to_string());
                Err(anyhow::anyhow!("batcher panicked: {what}"))
            });
            // Dead first, then drain: submit checks the flag under the
            // queue lock, so a racing request either sees the flag or its
            // queued entry is dropped here and the receiver disconnects.
            s2.dead.store(true, Ordering::SeqCst);
            unpoison(&s2.queue).clear();
            result
        });
        Server { shared, worker: Some(worker), next_id: Mutex::new(1) }
    }

    /// Serve from resident packed weights on the native backend: prefill
    /// and every decode step compute directly on RaBitQ codes via
    /// `qgemm` — no AOT artifacts, no dense weight reads, zero
    /// dequantization on the request path.
    ///
    /// # Errors
    ///
    /// Typed [`KvqError`]s from KV config validation (a budget too small
    /// for one lane, bad bit-widths, shape mismatches) — checked here, at
    /// construction, so a misconfigured server never spawns a batcher that
    /// would die at its first allocation.
    pub fn start_native_packed(
        manifest: Manifest,
        params: ModelParams,
        packed: PackedLayers,
    ) -> Result<Server, KvqError> {
        Server::start_native_packed_with(manifest, params, packed, ServeConfig::default())
    }

    /// [`Server::start_native_packed`] with explicit [`ServeConfig`].
    pub fn start_native_packed_with(
        manifest: Manifest,
        params: ModelParams,
        packed: PackedLayers,
        cfg: ServeConfig,
    ) -> Result<Server, KvqError> {
        // Eager KV validation: model shape, sensitivity calibration (only
        // when the policy needs it), bit plan, lane count — every failure
        // is a typed construction error, not a batcher death.
        let model = NativeModel::new(&manifest).map_err(|e| KvqError::Shape(e.to_string()))?;
        let sens = kv_sensitivity_if_needed(&cfg, &model, &manifest, &params, Some(&packed))
            .map_err(|e| KvqError::Shape(format!("KV sensitivity calibration failed: {e}")))?;
        let resolved = resolve_kv(&cfg, &model, manifest.eval_batch, sens.as_ref())?;
        Ok(Server::start_impl(
            move || {
                let mut mrt = ModelRuntime::native(manifest)?;
                mrt.attach_packed(packed)?;
                Ok(mrt)
            },
            params,
            cfg,
            Some(resolved),
        ))
    }

    fn next_id(&self) -> u64 {
        let mut g = self.next_id.lock().unwrap();
        let id = *g;
        *g += 1;
        id
    }

    fn not_accepting(&self) -> bool {
        self.shared.dead.load(Ordering::SeqCst) || *unpoison(&self.shared.shutdown)
    }

    /// Shared admission path: validate, bound the queue, enqueue.
    fn admit(&self, act: Active) -> Result<(), AdmitError> {
        // Out-of-vocab prompt tokens would make the batcher's prefill
        // error out and kill the server; refuse them at the door once the
        // batcher has published its vocabulary. (Before it has, the
        // batcher-side guard in `batcher_loop` still drops them safely.)
        let vocab = self.shared.vocab.load(Ordering::SeqCst);
        if vocab > 0 {
            if let Some(&t) = act.req.prompt.iter().find(|&&t| t < 0 || t as usize >= vocab) {
                return Err(AdmitError::InvalidRequest(format!(
                    "prompt token {t} outside vocabulary 0..{vocab}"
                )));
            }
        }
        {
            let mut q = unpoison(&self.shared.queue);
            if self.shared.dead.load(Ordering::SeqCst) || *unpoison(&self.shared.shutdown) {
                return Err(AdmitError::NotAccepting);
            }
            if self.shared.max_queue > 0 && q.len() >= self.shared.max_queue {
                return Err(AdmitError::QueueFull);
            }
            q.push_back(act);
        }
        self.shared.cv.notify_one();
        Ok(())
    }

    /// Submit a request; returns the request id and a receiver for its
    /// [`Completion`].
    ///
    /// A `max_new_tokens` of 0 completes immediately with an empty token
    /// list (no model work, not counted in [`ServerStats`]).
    ///
    /// # Errors
    ///
    /// [`AdmitError::NotAccepting`] once the server stopped accepting
    /// work (after [`Server::shutdown`] began, or after the batcher
    /// thread exited — without this check the request would queue into a
    /// dead batcher and its receiver would block forever);
    /// [`AdmitError::QueueFull`] when a bounded queue is at capacity;
    /// [`AdmitError::InvalidRequest`] for prompts the model can never
    /// serve.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
        seed: u64,
    ) -> Result<(u64, mpsc::Receiver<Completion>), AdmitError> {
        let id = self.next_id();
        let (tx, rx) = mpsc::channel();
        if max_new_tokens == 0 {
            // no model work, but the NotAccepting contract still holds: a
            // shut-down server must not answer any request successfully
            if self.not_accepting() {
                return Err(AdmitError::NotAccepting);
            }
            let _ = tx.send(Completion { id, tokens: Vec::new(), latency_secs: 0.0, steps: 0 });
            return Ok((id, rx));
        }
        self.admit(Active {
            req: Request { id, prompt, max_new_tokens, temperature, seed },
            generated: Vec::new(),
            submitted: Instant::now(),
            steps: 0,
            cancel: CancelToken::new(),
            sink: Sink::Complete(tx),
            rid: trace::current_rid().unwrap_or_else(trace::mint_rid),
            enqueued_us: trace::tracer().now_us(),
        })?;
        Ok((id, rx))
    }

    /// Submit a request whose tokens are delivered one by one as they are
    /// sampled — the transport behind the HTTP API's chunked streaming.
    ///
    /// The returned [`StreamHandle`] carries the event receiver (see
    /// [`StreamEvent`] for the protocol) and a [`CancelToken`]: cancelling
    /// — or simply dropping the receiver — frees the request's KV lane at
    /// the batcher's next round instead of generating to completion.
    ///
    /// A `max_new_tokens` of 0 completes immediately (a lone `Done`).
    ///
    /// # Errors
    ///
    /// Same admission errors as [`Server::submit`].
    pub fn submit_streaming(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
        seed: u64,
    ) -> Result<StreamHandle, AdmitError> {
        let id = self.next_id();
        let (tx, rx) = mpsc::channel();
        let cancel = CancelToken::new();
        if max_new_tokens == 0 {
            if self.not_accepting() {
                return Err(AdmitError::NotAccepting);
            }
            let _ = tx.send(StreamEvent::Done(Completion {
                id,
                tokens: Vec::new(),
                latency_secs: 0.0,
                steps: 0,
            }));
            return Ok(StreamHandle { id, events: rx, cancel });
        }
        self.admit(Active {
            req: Request { id, prompt, max_new_tokens, temperature, seed },
            generated: Vec::new(),
            submitted: Instant::now(),
            steps: 0,
            cancel: cancel.clone(),
            sink: Sink::Stream(tx),
            rid: trace::current_rid().unwrap_or_else(trace::mint_rid),
            enqueued_us: trace::tracer().now_us(),
        })?;
        Ok(StreamHandle { id, events: rx, cancel })
    }

    /// True while the batcher thread is alive and accepting submissions.
    pub fn is_running(&self) -> bool {
        !self.shared.dead.load(Ordering::SeqCst)
    }

    /// Live [`ServerStats`] snapshot, refreshed by the batcher once per
    /// scheduling round — unlike [`Server::shutdown`], this answers while
    /// generation is in flight (the HTTP `/v1/stats` endpoint). The
    /// snapshot's latency vector holds only the trailing
    /// [`LIVE_LATENCY_WINDOW`] completions, so its percentiles read
    /// recent traffic; the shutdown stats keep the full history.
    pub fn stats(&self) -> ServerStats {
        unpoison(&self.shared.live).clone()
    }

    /// Requests admitted but not yet mapped onto a KV lane.
    pub fn queue_depth(&self) -> usize {
        unpoison(&self.shared.queue).len()
    }

    /// Test hook: make the batcher panic at the top of its next
    /// scheduling round, simulating a bug in model code. The panic is
    /// contained (see `start_impl`): the server marks itself dead,
    /// in-flight receivers disconnect, and [`Server::shutdown`] returns
    /// the panic as an error.
    #[doc(hidden)]
    pub fn inject_batcher_panic(&self) {
        self.shared.panic_inject.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
    }

    /// Stop the batcher (after draining in-flight and queued work) and
    /// collect stats.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        {
            let mut s = unpoison(&self.shared.shutdown);
            *s = true;
        }
        self.shared.cv.notify_all();
        let handle = self.worker.take().expect("not yet shut down");
        handle.join().map_err(|_| anyhow::anyhow!("batcher panicked"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.worker.is_some() {
            {
                let mut s = unpoison(&self.shared.shutdown);
                *s = true;
            }
            self.shared.cv.notify_all();
            if let Some(h) = self.worker.take() {
                let _ = h.join();
            }
        }
    }
}

/// The request's full context (prompt + generated so far), truncated to
/// the trailing `seq` tokens — exactly the window the recompute reference
/// evaluates. Empty prompts fall back to a single `0` token so prefill
/// always has at least one position.
fn context_window(act: &Active, seq: usize) -> Vec<i32> {
    let mut ctx: Vec<i32> = act
        .req
        .prompt
        .iter()
        .chain(act.generated.iter())
        .copied()
        .collect();
    if ctx.is_empty() {
        ctx.push(0);
    }
    if ctx.len() > seq {
        ctx.drain(..ctx.len() - seq);
    }
    ctx
}

/// Sample one token from `logits` for `act`, then either complete the
/// request (send the [`Completion`], free the cache lane, return `None`)
/// or hand the still-active request back. A cancelled request — or one
/// whose stream receiver disappeared — is abandoned here: lane freed, no
/// completion sent, sender dropped so receivers disconnect.
fn settle(
    mut act: Active,
    logits: &[f32],
    cache: &mut KvCache,
    slot: usize,
    stats: &mut ServerStats,
) -> Option<Active> {
    if act.cancel.is_cancelled() {
        cache.reset(slot);
        stats.cancelled += 1;
        obs::metrics().cancelled.inc();
        return None;
    }
    let tok = softmax_sample(logits, act.req.temperature, act.req.seed, act.steps);
    act.generated.push(tok);
    act.steps += 1;
    stats.tokens_generated += 1;
    obs::metrics().tokens_generated.inc();
    if !act.sink.token(act.req.id, act.generated.len() - 1, tok) {
        cache.reset(slot);
        stats.cancelled += 1;
        obs::metrics().cancelled.inc();
        return None;
    }
    if act.generated.len() >= act.req.max_new_tokens {
        let latency = act.submitted.elapsed().as_secs_f64();
        stats.latencies.push(latency);
        stats.completions += 1;
        obs::metrics().completions.inc();
        act.sink.done(Completion {
            id: act.req.id,
            tokens: act.generated,
            latency_secs: latency,
            steps: act.steps,
        });
        cache.reset(slot);
        None
    } else {
        Some(act)
    }
}

fn batcher_loop(
    shared: &Shared,
    mrt: ModelRuntime,
    params: ModelParams,
    cfg: &ServeConfig,
    resolved: Option<ResolvedKv>,
) -> Result<ServerStats> {
    let m = &mrt.manifest;
    let (seq, vocab) = (m.seq_len, m.vocab);
    shared.vocab.store(vocab, Ordering::SeqCst);
    // Factory-path servers resolve their KV config here (the eager path
    // already did it at construction and handed the result in).
    let resolved = match resolved {
        Some(r) => r,
        None => {
            let sens =
                kv_sensitivity_if_needed(cfg, &mrt.native_model, m, &params, mrt.packed())?;
            resolve_kv(cfg, &mrt.native_model, m.eval_batch, sens.as_ref())?
        }
    };
    let batch = resolved.lanes;
    let mut cache = match &resolved.plan {
        None => mrt.new_kv_cache(batch),
        Some(plan) => {
            mrt.new_kv_cache_quantized(batch, plan.clone(), kvq::DEFAULT_ROT_SEED)?
        }
    };
    let mut lanes: Vec<Option<Active>> = (0..batch).map(|_| None).collect();
    let mut stats = ServerStats {
        kv_bits: resolved.kv_bits,
        kv_bytes_per_lane: resolved.bytes_per_lane,
        lanes: batch,
        ..Default::default()
    };
    let start = Instant::now();

    loop {
        // ---- test hook: simulate a bug in model code killing a round
        if shared.panic_inject.load(Ordering::SeqCst) {
            panic!("injected batcher panic (test hook)");
        }

        // ---- free lanes whose requests were cancelled since last round
        // (dropped HTTP connections land here): reset the KV lane so the
        // admission pass below can hand it to the next request
        for slot in 0..batch {
            let cancelled = lanes[slot].as_ref().is_some_and(|a| a.cancel.is_cancelled());
            if cancelled {
                lanes[slot] = None;
                cache.reset(slot);
                stats.cancelled += 1;
                obs::metrics().cancelled.inc();
            }
        }

        // ---- admit queued requests into free lanes: one prefill each,
        // which also yields the request's first token
        'slots: for slot in 0..batch {
            if lanes[slot].is_some() {
                continue;
            }
            loop {
                let Some(act) = shared.queue.lock().unwrap().pop_front() else {
                    break 'slots;
                };
                // cancelled while queued: drop without model work
                if act.cancel.is_cancelled() {
                    stats.cancelled += 1;
                    obs::metrics().cancelled.inc();
                    continue;
                }
                // Backstop for the race in `Server::admit` before the
                // vocabulary is published: an out-of-vocab prompt must
                // never reach `prefill` (its error would kill the
                // batcher). Dropping the sink disconnects the receiver.
                if act.req.prompt.iter().any(|&t| t < 0 || t as usize >= vocab) {
                    stats.cancelled += 1;
                    obs::metrics().cancelled.inc();
                    continue;
                }
                // the admission-to-lane wait ends here; time the prefill
                // separately so the two phases stay distinguishable
                let t = trace::tracer();
                let lane_at = t.now_us();
                let waited = lane_at.saturating_sub(act.enqueued_us);
                obs::metrics().queue_wait_us.observe_us(waited);
                t.record(&act.rid, "queue_wait", act.enqueued_us, waited, -1);
                let window = context_window(&act, seq);
                let logits = mrt.prefill(&params, &mut cache, slot, &window)?;
                let dur = t.now_us().saturating_sub(lane_at);
                obs::metrics().prefill_us.observe_us(dur);
                t.record(&act.rid, "prefill", lane_at, dur, window.len() as i64);
                stats.batch_steps += 1;
                stats.total_rows += 1;
                stats.prefill_tokens += window.len();
                lanes[slot] = settle(act, &logits, &mut cache, slot, &mut stats);
                break;
            }
        }

        // ---- idle: wait for work or shutdown
        if lanes.iter().all(|l| l.is_none()) {
            stats.lanes_active = 0;
            publish_stats(shared, &mut stats, start);
            let mut q = shared.queue.lock().unwrap();
            loop {
                if !q.is_empty() || shared.panic_inject.load(Ordering::SeqCst) {
                    break;
                }
                if *shared.shutdown.lock().unwrap() {
                    drop(q);
                    stats.wall_secs = start.elapsed().as_secs_f64();
                    publish_stats(shared, &mut stats, start);
                    return Ok(stats);
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, std::time::Duration::from_millis(20))
                    .unwrap();
                q = guard;
            }
            continue;
        }

        // ---- full windows slide via re-prefill (absolute position
        // embeddings re-position every token, so the cached rows are
        // stale by construction; in-window lanes stay on the fast path)
        for slot in 0..batch {
            let Some(act) = lanes[slot].take() else { continue };
            if act.cancel.is_cancelled() {
                cache.reset(slot);
                stats.cancelled += 1;
                obs::metrics().cancelled.inc();
                continue;
            }
            if !cache.is_full(slot) {
                lanes[slot] = Some(act);
                continue;
            }
            let t = trace::tracer();
            let t0 = t.now_us();
            let window = context_window(&act, seq);
            let logits = mrt.prefill(&params, &mut cache, slot, &window)?;
            let dur = t.now_us().saturating_sub(t0);
            obs::metrics().prefill_us.observe_us(dur);
            t.record(&act.rid, "prefill", t0, dur, window.len() as i64);
            stats.batch_steps += 1;
            stats.total_rows += 1;
            stats.prefill_tokens += window.len();
            stats.window_slides += 1;
            obs::metrics().window_slides.inc();
            lanes[slot] = settle(act, &logits, &mut cache, slot, &mut stats);
        }

        // ---- fixed-shape batched decode over the remaining active lanes
        let decode: Vec<usize> = (0..batch)
            .filter(|&s| lanes[s].is_some() && !cache.is_full(s))
            .collect();
        if !decode.is_empty() {
            let tokens: Vec<i32> = decode
                .iter()
                .map(|&s| *lanes[s].as_ref().unwrap().generated.last().unwrap())
                .collect();
            let t = trace::tracer();
            let t0 = t.now_us();
            let rows = mrt.decode_step(&params, &mut cache, &decode, &tokens)?;
            let dur = t.now_us().saturating_sub(t0);
            obs::metrics().decode_step_us.observe_us(dur);
            if t.is_enabled() {
                // one span per lane sharing the step's duration (the
                // step is batched; per-lane attribution is the shape a
                // request's span tree needs), note = 0-based index of
                // the token this step samples for that lane
                for &slot in &decode {
                    let act = lanes[slot].as_ref().expect("decode lane is active");
                    t.record(&act.rid, "decode", t0, dur, act.generated.len() as i64);
                }
            }
            stats.batch_steps += 1;
            stats.total_rows += decode.len();
            stats.decode_steps += 1;
            for (i, &slot) in decode.iter().enumerate() {
                let act = lanes[slot].take().expect("decode lane is active");
                let logits = &rows[i * vocab..(i + 1) * vocab];
                lanes[slot] = settle(act, logits, &mut cache, slot, &mut stats);
            }
        }

        stats.lanes_active = lanes.iter().filter(|l| l.is_some()).count();
        publish_stats(shared, &mut stats, start);
    }
}

/// Completed-request latencies retained in the **live** snapshot (the
/// full history stays in the batcher-local stats returned by
/// [`Server::shutdown`]). Bounding the snapshot keeps the per-round
/// publish O(window) instead of O(total completions) — the batcher
/// republishes once per scheduling round, which is roughly once per
/// generated token.
pub const LIVE_LATENCY_WINDOW: usize = 512;

/// Refresh the shared live snapshot. Cheap by construction: every field
/// is a counter except the latency vector, which is truncated to the
/// trailing [`LIVE_LATENCY_WINDOW`] entries (so live p50/p95 are over
/// recent traffic — the more useful operational read anyway).
fn publish_stats(shared: &Shared, stats: &mut ServerStats, start: Instant) {
    stats.wall_secs = start.elapsed().as_secs_f64();
    stats.queue_depth = shared.queue.lock().unwrap().len();
    obs::metrics().queue_depth.set(stats.queue_depth as i64);
    obs::metrics().lanes_active.set(stats.lanes_active as i64);
    let from = stats.latencies.len().saturating_sub(LIVE_LATENCY_WINDOW);
    let snap = ServerStats {
        completions: stats.completions,
        batch_steps: stats.batch_steps,
        total_rows: stats.total_rows,
        tokens_generated: stats.tokens_generated,
        prefill_tokens: stats.prefill_tokens,
        decode_steps: stats.decode_steps,
        window_slides: stats.window_slides,
        cancelled: stats.cancelled,
        latencies: stats.latencies[from..].to_vec(),
        wall_secs: stats.wall_secs,
        kv_bits: stats.kv_bits,
        kv_bytes_per_lane: stats.kv_bytes_per_lane,
        lanes: stats.lanes,
        lanes_active: stats.lanes_active,
        queue_depth: stats.queue_depth,
    };
    *shared.live.lock().unwrap() = snap;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_manifest;
    use crate::quant::{LayerCalib, TrickConfig};
    use crate::runtime::{native_init, PackedLayers};

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = vec![0.1f32, 2.0, -1.0, 1.9];
        assert_eq!(softmax_sample(&logits, 0.0, 0, 0), 1);
    }

    #[test]
    fn temperature_sampling_in_range_and_seeded() {
        let logits = vec![0.0f32; 16];
        let a = softmax_sample(&logits, 1.0, 42, 3);
        let b = softmax_sample(&logits, 1.0, 42, 3);
        assert_eq!(a, b);
        assert!((0..16).contains(&a));
    }

    #[test]
    fn sampling_all_equal_logits_covers_range() {
        // all-equal logits: every index must be reachable, none preferred
        let logits = vec![1.5f32; 8];
        let mut seen = [false; 8];
        for seed in 0..256u64 {
            seen[softmax_sample(&logits, 0.7, seed, 0) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform sampling missed an index: {seen:?}");
    }

    #[test]
    fn sampling_neg_inf_logits_never_panics() {
        // all -inf: no softmax exists; must fall back to greedy, not panic
        let all = vec![f32::NEG_INFINITY; 4];
        assert_eq!(softmax_sample(&all, 1.0, 7, 2), 0);
        // one finite survivor among -inf gets all the mass
        let mut one = vec![f32::NEG_INFINITY; 5];
        one[3] = 0.25;
        for seed in 0..32u64 {
            assert_eq!(softmax_sample(&one, 1.0, seed, 1), 3);
        }
        // NaN entries must never be selected
        let with_nan = vec![f32::NAN, 1.0, f32::NAN, 0.5];
        for seed in 0..32u64 {
            let t = softmax_sample(&with_nan, 1.0, seed, 0);
            assert!(t == 1 || t == 3, "picked NaN logit at index {t}");
        }
    }

    #[test]
    fn sampling_near_zero_temperature_is_argmax() {
        let logits = vec![0.1f32, 2.0, -1.0, 1.9];
        for seed in 0..32u64 {
            assert_eq!(softmax_sample(&logits, 1e-30, seed, 0), 1);
        }
    }

    fn packed_fixture(
        name: &str,
        seq_len: usize,
        eval_batch: usize,
        seed: u64,
    ) -> (Manifest, ModelParams, PackedLayers) {
        let manifest = synthetic_manifest(name, 32, 1, 2, 64, seq_len, 256, eval_batch);
        let params = native_init(&manifest, seed);
        let stats: Vec<LayerCalib> =
            manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; manifest.linears.len()];
        let packed = PackedLayers::quantize(
            &manifest, &params, &bits, &stats, &TrickConfig::none(), 1, 1,
        )
        .unwrap();
        (manifest, params, packed)
    }

    #[test]
    fn native_packed_server_generates_tokens() {
        let (manifest, params, packed) = packed_fixture("serve-native", 8, 2, 17);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let (_, rx) = server.submit(vec![1, 2, 3], 4, 0.0, 0).unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 4);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.completions, 1);
        assert_eq!(stats.tokens_generated, 4);
        // 1 admission prefill + 3 decode rounds (no slides: 3 + 4 <= 8)
        assert_eq!(stats.prefill_tokens, 3);
        assert_eq!(stats.window_slides, 0);
        assert!(stats.decode_steps >= 3);
    }

    #[test]
    fn kv_server_slides_window_past_context() {
        // seq_len 8, 20 generated tokens: the lane must slide repeatedly
        let (manifest, params, packed) = packed_fixture("serve-slide", 8, 1, 23);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let (_, rx) = server.submit(vec![9, 8, 7], 20, 0.7, 5).unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 20);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.completions, 1);
        assert_eq!(stats.tokens_generated, 20);
        assert!(
            stats.window_slides >= 10,
            "window_slides {} — beyond-context generation must slide",
            stats.window_slides
        );
    }

    #[test]
    fn zero_token_request_completes_empty() {
        let (manifest, params, packed) = packed_fixture("serve-zero", 8, 1, 31);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let (_, rx) = server.submit(vec![1, 2], 0, 0.0, 0).unwrap();
        let c = rx.recv().unwrap();
        assert!(c.tokens.is_empty(), "asked for zero tokens, got {:?}", c.tokens);
        assert_eq!(c.steps, 0);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.tokens_generated, 0);
    }

    #[test]
    fn empty_prompt_is_served() {
        let (manifest, params, packed) = packed_fixture("serve-empty", 8, 1, 29);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let (_, rx) = server.submit(Vec::new(), 3, 0.0, 0).unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 3);
        server.shutdown().unwrap();
    }

    #[test]
    fn submit_into_dead_batcher_errors_not_hangs() {
        let manifest = synthetic_manifest("serve-dead", 16, 1, 2, 32, 8, 64, 1);
        let params = native_init(&manifest, 1);
        let server = Server::start(|| anyhow::bail!("factory exploded"), params);
        let mut waited = 0;
        while server.is_running() && waited < 500 {
            std::thread::sleep(std::time::Duration::from_millis(10));
            waited += 1;
        }
        assert!(!server.is_running(), "worker should have died");
        assert!(server.submit(vec![1], 3, 0.0, 0).is_err());
        // even the no-model-work fast path must refuse (NotAccepting)
        assert!(server.submit(vec![1], 0, 0.0, 0).is_err());
        assert!(server.submit_streaming(vec![1], 0, 0.0, 0).is_err());
        // shutdown surfaces the factory error instead of stats
        assert!(server.shutdown().is_err());
    }

    #[test]
    fn receivers_disconnect_when_batcher_dies() {
        let manifest = synthetic_manifest("serve-late", 16, 1, 2, 32, 8, 64, 1);
        let params = native_init(&manifest, 2);
        let server = Server::start(
            || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                anyhow::bail!("late failure")
            },
            params,
        );
        // this submit may race the death either way; both outcomes are
        // lifecycle-correct — an error, or a receiver that disconnects
        if let Ok((_, rx)) = server.submit(vec![1], 2, 0.0, 0) {
            assert!(rx.recv().is_err(), "receiver must disconnect, not hang");
        }
        assert!(server.shutdown().is_err());
    }

    #[test]
    fn batcher_panic_is_contained_not_a_hang() {
        let (manifest, params, packed) = packed_fixture("serve-panic", 8, 1, 37);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        // a long generation pins the lane so the panic hits mid-stream
        let (_, rx) = server.submit(vec![1, 2], 1_000_000, 0.0, 0).unwrap();
        let mut waited = 0;
        while server.stats().tokens_generated == 0 && waited < 1000 {
            std::thread::sleep(std::time::Duration::from_millis(5));
            waited += 1;
        }
        assert!(server.stats().tokens_generated > 0, "generation never started");
        server.inject_batcher_panic();
        // the in-flight receiver disconnects instead of hanging forever
        assert!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).is_err(),
            "in-flight receiver must disconnect after the panic"
        );
        let mut waited = 0;
        while server.is_running() && waited < 1000 {
            std::thread::sleep(std::time::Duration::from_millis(5));
            waited += 1;
        }
        assert!(!server.is_running(), "panicked batcher must mark itself dead");
        // post-panic, submitters get a typed refusal and the observability
        // surface keeps answering even if a lock was poisoned mid-round
        assert!(matches!(server.submit(vec![1], 3, 0.0, 0), Err(AdmitError::NotAccepting)));
        let _ = server.stats();
        let _ = server.queue_depth();
        let err = server.shutdown().expect_err("shutdown must surface the panic");
        assert!(
            err.to_string().contains("panic"),
            "shutdown error should name the panic, got: {err}"
        );
    }

    #[test]
    fn stats_math() {
        let s = ServerStats {
            completions: 2,
            batch_steps: 4,
            total_rows: 12,
            tokens_generated: 40,
            latencies: vec![0.1, 0.2],
            wall_secs: 2.0,
            ..Default::default()
        };
        assert!((s.mean_batch_occupancy(4) - 0.75).abs() < 1e-12);
        assert!((s.throughput_tok_s() - 20.0).abs() < 1e-12);
        assert!(s.p95_latency() >= s.p50_latency());
    }

    #[test]
    fn stats_percentiles_tolerate_empty_and_single() {
        // the live snapshot is polled before any completion exists: the
        // percentile helpers must not panic on empty latency vectors
        let empty = ServerStats::default();
        assert_eq!(empty.p50_latency(), 0.0);
        assert_eq!(empty.p95_latency(), 0.0);
        assert_eq!(empty.throughput_tok_s(), 0.0);
        assert_eq!(empty.mean_batch_occupancy(4), 0.0);
        let one = ServerStats { latencies: vec![0.25], ..Default::default() };
        assert_eq!(one.p50_latency(), 0.25);
        assert_eq!(one.p95_latency(), 0.25);
    }

    #[test]
    fn streaming_tokens_match_nonstreamed_completion() {
        let (manifest, params, packed) = packed_fixture("serve-stream", 8, 2, 41);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        // greedy: both paths must walk the identical token sequence
        let (_, rx) = server.submit(vec![5, 6, 7], 5, 0.0, 0).unwrap();
        let want = rx.recv().unwrap().tokens;

        let handle = server.submit_streaming(vec![5, 6, 7], 5, 0.0, 0).unwrap();
        let mut streamed = Vec::new();
        let mut done = None;
        for ev in handle.events.iter() {
            match ev {
                StreamEvent::Token { index, token, id } => {
                    assert_eq!(id, handle.id);
                    assert_eq!(index, streamed.len(), "events must arrive in order");
                    streamed.push(token);
                }
                StreamEvent::Done(c) => {
                    done = Some(c);
                    break;
                }
            }
        }
        let done = done.expect("stream must end with Done");
        assert_eq!(done.tokens, streamed, "Done must carry the streamed tokens");
        assert_eq!(streamed, want, "streamed != non-streamed for greedy sampling");
        server.shutdown().unwrap();
    }

    #[test]
    fn streaming_zero_tokens_is_immediate_done() {
        let (manifest, params, packed) = packed_fixture("serve-stream0", 8, 1, 43);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let handle = server.submit_streaming(vec![1], 0, 0.0, 0).unwrap();
        match handle.events.recv().unwrap() {
            StreamEvent::Done(c) => assert!(c.tokens.is_empty()),
            ev => panic!("expected immediate Done, got {ev:?}"),
        }
        server.shutdown().unwrap();
    }

    #[test]
    fn cancellation_frees_the_lane() {
        // single lane; first request would generate (effectively) forever
        let (manifest, params, packed) = packed_fixture("serve-cancel", 8, 1, 47);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let handle = server.submit_streaming(vec![1, 2], 1_000_000, 0.5, 3).unwrap();
        // wait until it owns the lane (first token proves prefill ran)
        let first = handle.events.recv_timeout(std::time::Duration::from_secs(30));
        assert!(first.is_ok(), "first token never arrived");
        handle.cancel.cancel();
        // the lane must come free: a second request admits and completes
        let (_, rx) = server.submit(vec![3, 4], 3, 0.0, 0).unwrap();
        let c = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens.len(), 3);
        // the cancelled stream disconnects without a Done
        loop {
            match handle.events.recv_timeout(std::time::Duration::from_secs(30)) {
                Ok(StreamEvent::Done(_)) => panic!("cancelled request must not complete"),
                Ok(StreamEvent::Token { .. }) => continue,
                Err(_) => break, // disconnected (or drained): cancelled
            }
        }
        let stats = server.shutdown().unwrap();
        assert!(stats.cancelled >= 1, "cancellation must be counted");
        assert_eq!(stats.completions, 1);
    }

    #[test]
    fn dropping_stream_receiver_cancels() {
        let (manifest, params, packed) = packed_fixture("serve-droprx", 8, 1, 53);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let handle = server.submit_streaming(vec![9], 1_000_000, 0.3, 1).unwrap();
        // receiving one token proves the request owns the lane; then drop
        // the receiver without cancelling explicitly
        assert!(handle.events.recv_timeout(std::time::Duration::from_secs(30)).is_ok());
        drop(handle);
        let (_, rx) = server.submit(vec![2], 2, 0.0, 0).unwrap();
        let c = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens.len(), 2);
        let stats = server.shutdown().unwrap();
        assert!(stats.cancelled >= 1);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let (manifest, params, packed) = packed_fixture("serve-429", 8, 1, 59);
        let server = Server::start_native_packed_with(
            manifest,
            params,
            packed,
            ServeConfig { max_queue: 1, ..Default::default() },
        )
        .unwrap();
        // A occupies the single lane (first token proves it left the queue)
        let a = server.submit_streaming(vec![1], 1_000_000, 0.4, 2).unwrap();
        assert!(a.events.recv_timeout(std::time::Duration::from_secs(30)).is_ok());
        // B fills the queue; C must be refused, not silently queued
        let b = server.submit(vec![2], 2, 0.0, 0).unwrap();
        let c = server.submit(vec![3], 2, 0.0, 0);
        assert_eq!(c.unwrap_err(), AdmitError::QueueFull);
        assert_eq!(server.queue_depth(), 1, "rejected request must not be queued");
        // free the lane: B drains
        a.cancel.cancel();
        let done = b.1.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(done.tokens.len(), 2);
        server.shutdown().unwrap();
    }

    #[test]
    fn live_stats_update_mid_flight() {
        let (manifest, params, packed) = packed_fixture("serve-live", 8, 1, 61);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let handle = server.submit_streaming(vec![4, 5], 1_000_000, 0.6, 9).unwrap();
        // after a few tokens the live snapshot must show progress even
        // though nothing has completed
        for _ in 0..3 {
            assert!(handle.events.recv_timeout(std::time::Duration::from_secs(30)).is_ok());
        }
        let mut live = server.stats();
        for _ in 0..200 {
            if live.tokens_generated > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
            live = server.stats();
        }
        assert!(live.tokens_generated > 0, "live stats never reflected progress");
        assert_eq!(live.completions, 0);
        handle.cancel.cancel();
        server.shutdown().unwrap();
    }

    #[test]
    fn live_snapshot_republishes_queue_depth() {
        // single lane, one request pinning it and one queued behind it:
        // the live snapshot itself must carry the queue depth (the
        // /v1/stats surface reads the snapshot, not the server handle)
        let (manifest, params, packed) = packed_fixture("serve-qdepth", 8, 1, 97);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        let a = server.submit_streaming(vec![1], 1_000_000, 0.4, 2).unwrap();
        assert!(a.events.recv_timeout(std::time::Duration::from_secs(30)).is_ok());
        let b = server.submit(vec![2], 2, 0.0, 0).unwrap();
        let mut seen = 0usize;
        for _ in 0..500 {
            seen = server.stats().queue_depth;
            if seen > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(seen, 1, "snapshot must republish the queued request");
        a.cancel.cancel();
        assert_eq!(b.1.recv_timeout(std::time::Duration::from_secs(30)).unwrap().tokens.len(), 2);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.queue_depth, 0, "shutdown stats report a drained queue");
    }

    #[test]
    fn out_of_vocab_prompt_is_refused_not_fatal() {
        let (manifest, params, packed) = packed_fixture("serve-vocab", 8, 1, 67);
        let server = Server::start_native_packed(manifest, params, packed).unwrap();
        // a served request proves the batcher is up (vocab published)
        let (_, rx) = server.submit(vec![1], 1, 0.0, 0).unwrap();
        rx.recv().unwrap();
        // vocab is 256 in the fixture: token 300 can never be embedded
        match server.submit(vec![300], 4, 0.0, 0) {
            Err(AdmitError::InvalidRequest(_)) => {}
            other => panic!("expected InvalidRequest, got {:?}", other.map(|(id, _)| id)),
        }
        assert_eq!(
            server.submit(vec![-1], 4, 0.0, 0).unwrap_err(),
            AdmitError::InvalidRequest("prompt token -1 outside vocabulary 0..256".into())
        );
        // the server survived: valid traffic still flows
        let (_, rx) = server.submit(vec![2], 2, 0.0, 0).unwrap();
        assert_eq!(rx.recv().unwrap().tokens.len(), 2);
        server.shutdown().unwrap();
    }

    /// Poll the live snapshot until the batcher has published its lane
    /// setup (first idle round), bounded at ~5 s.
    fn wait_lanes(server: &Server) -> ServerStats {
        for _ in 0..500 {
            let s = server.stats();
            if s.lanes > 0 {
                return s;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("batcher never published its lane setup");
    }

    #[test]
    fn quantized_kv_server_generates_and_reports_bits() {
        let (manifest, params, packed) = packed_fixture("serve-kvq", 8, 2, 71);
        let server = Server::start_native_packed_with(
            manifest,
            params,
            packed,
            ServeConfig { kv: KvqPolicy::Uniform(4), ..Default::default() },
        )
        .unwrap();
        let live = wait_lanes(&server);
        assert_eq!(live.kv_bits, 4.0);
        assert_eq!(live.lanes, 2, "no budget: lane pool stays eval_batch");
        assert!(live.kv_bytes_per_lane > 0);
        let (_, rx) = server.submit(vec![1, 2, 3], 6, 0.0, 0).unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 6);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.completions, 1);
        assert_eq!(stats.kv_bits, 4.0);
    }

    #[test]
    fn kv_budget_scales_lane_count_vs_dense() {
        // same total KV budget, f32 vs 4-bit: the quantized pool must fit
        // at least 2x the lanes (the acceptance-criterion ratio)
        let budget = {
            let (manifest, _, _) = packed_fixture("serve-kvq-probe", 8, 1, 73);
            let model = NativeModel::new(&manifest).unwrap();
            3 * kvq::dense_bytes_per_lane(model.n_layers, model.seq_len, model.d_model)
        };
        let lanes_of = |kv: KvqPolicy| {
            let (manifest, params, packed) = packed_fixture("serve-kvq-lanes", 8, 1, 73);
            let server = Server::start_native_packed_with(
                manifest,
                params,
                packed,
                ServeConfig { kv, kv_budget_bytes: budget, ..Default::default() },
            )
            .unwrap();
            let lanes = wait_lanes(&server).lanes;
            server.shutdown().unwrap();
            lanes
        };
        let dense = lanes_of(KvqPolicy::DenseF32);
        let quant = lanes_of(KvqPolicy::Uniform(4));
        assert_eq!(dense, 3, "budget sized for exactly 3 dense lanes");
        assert!(
            quant >= 2 * dense,
            "4-bit KV must fit >= 2x the lanes of f32: {quant} vs {dense}"
        );
    }

    #[test]
    fn kv_budget_too_small_is_typed_construction_error() {
        let (manifest, params, packed) = packed_fixture("serve-kvq-small", 8, 1, 79);
        let err = Server::start_native_packed_with(
            manifest,
            params,
            packed,
            ServeConfig {
                kv: KvqPolicy::Uniform(4),
                kv_budget_bytes: 64,
                ..Default::default()
            },
        )
        .err()
        .expect("a 64-byte KV budget must be refused at construction");
        match err {
            KvqError::BudgetTooSmall { budget_bytes, min_lane_bytes } => {
                assert_eq!(budget_bytes, 64);
                assert!(min_lane_bytes > 64);
            }
            other => panic!("expected BudgetTooSmall, got {other:?}"),
        }
        // bad bit-widths are refused the same way
        let (manifest, params, packed) = packed_fixture("serve-kvq-bits", 8, 1, 79);
        assert_eq!(
            Server::start_native_packed_with(
                manifest,
                params,
                packed,
                ServeConfig { kv: KvqPolicy::Uniform(9), ..Default::default() },
            )
            .err(),
            Some(KvqError::BadBits(9))
        );
    }

    #[test]
    fn kv_budget_policy_solves_per_layer_plan() {
        // --kv-budget without --kv-bits: AllocateBits picks per-layer
        // widths under the per-lane share; the server still serves
        let (manifest, params, packed) = packed_fixture("serve-kvq-plan", 8, 2, 83);
        let model = NativeModel::new(&manifest).unwrap();
        let budget =
            4 * kvq::KvqPlan::uniform(model.n_layers, 4)
                .unwrap()
                .bytes_per_lane(model.seq_len, model.d_model, model.n_heads);
        let server = Server::start_native_packed_with(
            manifest,
            params,
            packed,
            ServeConfig {
                kv: KvqPolicy::Budget { bit_choices: vec![2, 4, 8] },
                kv_budget_bytes: budget,
                ..Default::default()
            },
        )
        .unwrap();
        let live = wait_lanes(&server);
        assert!(live.kv_bits > 0.0 && live.kv_bits < 32.0, "kv_bits {}", live.kv_bits);
        assert!(live.lanes >= 2, "budget sized for multiple lanes, got {}", live.lanes);
        let (_, rx) = server.submit(vec![4, 5], 4, 0.0, 0).unwrap();
        assert_eq!(rx.recv().unwrap().tokens.len(), 4);
        server.shutdown().unwrap();
    }

    #[test]
    fn kv_budget_below_equal_share_still_fits_one_lane() {
        // eval_batch 2, total budget = exactly one cheapest (2-bit) lane:
        // the equal-share heuristic would cap each lane at half that, but
        // the budget genuinely fits a lane — construction must fall back
        // to the cheapest lane size, not report BudgetTooSmall
        let (manifest, params, packed) = packed_fixture("serve-kvq-tight", 8, 2, 89);
        let model = NativeModel::new(&manifest).unwrap();
        let min_lane = kvq::KvqPlan::uniform(model.n_layers, 2)
            .unwrap()
            .bytes_per_lane(model.seq_len, model.d_model, model.n_heads);
        let server = Server::start_native_packed_with(
            manifest,
            params,
            packed,
            ServeConfig {
                kv: KvqPolicy::Budget { bit_choices: vec![2, 4, 8] },
                kv_budget_bytes: min_lane,
                ..Default::default()
            },
        )
        .unwrap();
        let live = wait_lanes(&server);
        assert_eq!(live.lanes, 1, "exactly one cheapest lane fits");
        assert!(live.kv_bits > 0.0 && live.kv_bits < 32.0);
        let (_, rx) = server.submit(vec![7], 3, 0.0, 0).unwrap();
        assert_eq!(rx.recv().unwrap().tokens.len(), 3);
        server.shutdown().unwrap();
    }
}
