//! Batching inference server: the L3 request path over quantized weights.
//!
//! Architecture (vLLM-router-style, scaled to this repo): callers submit
//! [`Request`]s to a [`Server`] handle; a batcher thread maps requests
//! onto a fixed pool of KV-cache lanes (`eval_batch` of them). Each newly
//! admitted request is **prefilled** once — its prompt runs through the
//! model a single time, depositing per-layer K/V rows into its lane of a
//! [`KvCache`] — and from then on rides fixed-shape **batched decode
//! steps**: one token per active lane per step, attending over cached
//! K/V instead of recomputing the window. Per-token cost is therefore
//! O(context) attention + O(1) linear work, not a full O(context)
//! forward; `benches/kernels.rs` records the resulting tokens/s win as
//! `serve_kv` vs `serve_recompute`.
//!
//! When a lane's window fills (context = `seq_len`), the batcher slides
//! it by re-prefilling the last `seq_len` tokens — the model's absolute
//! position embeddings re-position every token on a slide, so the cached
//! rows are genuinely stale and recompute is the correct (and reference-
//! exact) behavior. Python is never on this path; with packed weights
//! attached the decode linears run on RaBitQ codes via `qgemm`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::Result;

use crate::model::{Manifest, ModelParams};
use crate::runtime::{KvCache, ModelRuntime, PackedLayers};
use crate::util::percentile;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Greedy if 0.0, else temperature sampling with this temperature.
    pub temperature: f32,
    pub seed: u64,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub latency_secs: f64,
    /// Number of generation steps (one sampled token each: the prefill
    /// yields the first, every decode step or window slide one more).
    pub steps: usize,
}

struct Active {
    req: Request,
    generated: Vec<i32>,
    submitted: Instant,
    steps: usize,
    done_tx: mpsc::Sender<Completion>,
}

struct Shared {
    queue: Mutex<VecDeque<Active>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    /// Set by the batcher thread on exit (normal or error), *before* it
    /// drains the queue — [`Server::submit`] checks it under the queue
    /// lock so no request can be stranded behind a dead batcher.
    dead: AtomicBool,
}

/// Server handle.
///
/// # Lifecycle
///
/// 1. [`Server::start`] / [`Server::start_native_packed`] spawn the
///    batcher thread, which owns the runtime, the weights, and one
///    [`KvCache`] with `eval_batch` request lanes.
/// 2. [`Server::submit`] enqueues work while the batcher is alive. Once
///    shutdown has begun, or the batcher has exited (failed runtime
///    factory, forward error), `submit` returns an error instead of
///    queueing into a dead thread.
/// 3. [`Server::shutdown`] waits for in-flight **and** queued requests to
///    finish, joins the batcher, and returns its [`ServerStats`] (or its
///    error). Dropping the handle performs the same drain-and-join but
///    discards the result.
///
/// If the batcher dies early, receivers for already-queued requests
/// disconnect (`recv` returns `Err`) rather than blocking forever: the
/// exiting thread marks itself dead and then drains the queue.
pub struct Server {
    shared: Arc<Shared>,
    worker: Option<thread::JoinHandle<Result<ServerStats>>>,
    next_id: Mutex<u64>,
}

/// Aggregate metrics reported on shutdown.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub completions: usize,
    /// Model executions: prefills (admissions + window slides) plus
    /// batched decode steps.
    pub batch_steps: usize,
    /// Sequence rows processed across all executions (a prefill is one
    /// row, a batched decode is one row per active lane).
    pub total_rows: usize,
    pub tokens_generated: usize,
    /// Prompt tokens pushed through prefill (admissions + slides).
    pub prefill_tokens: usize,
    /// Batched decode executions (the KV fast path).
    pub decode_steps: usize,
    /// Full-window re-prefills (context outgrew `seq_len`).
    pub window_slides: usize,
    pub latencies: Vec<f64>,
    pub wall_secs: f64,
}

impl ServerStats {
    pub fn mean_batch_occupancy(&self, batch: usize) -> f64 {
        if self.batch_steps == 0 {
            return 0.0;
        }
        self.total_rows as f64 / (self.batch_steps * batch) as f64
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.wall_secs
    }

    pub fn p50_latency(&self) -> f64 {
        percentile(&self.latencies, 50.0)
    }

    pub fn p95_latency(&self) -> f64 {
        percentile(&self.latencies, 95.0)
    }
}

fn softmax_sample(logits: &[f32], temperature: f32, seed: u64, step: usize) -> i32 {
    if temperature <= 0.0 {
        return crate::util::argmax(logits) as i32;
    }
    let mut rng = crate::rng::Rng::new(seed ^ (step as u64).wrapping_mul(0x9E37));
    let maxl = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let exps: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - maxl) / temperature) as f64).exp())
        .collect();
    let mut cum = Vec::with_capacity(exps.len());
    let mut acc = 0.0;
    for e in exps {
        acc += e;
        cum.push(acc);
    }
    rng.sample_cumulative(&cum) as i32
}

impl Server {
    /// Start a server over `params` (typically quantized weights).
    ///
    /// PJRT handles are not `Send`, so the batcher thread constructs its
    /// own runtime via `factory` (e.g. `|| ModelRuntime::load(...)` with a
    /// fresh `Runtime::cpu()`); `params` moves into the thread. The lane
    /// pool is `eval_batch` wide and each lane's KV window is the model's
    /// `seq_len`.
    pub fn start<F>(factory: F, params: ModelParams) -> Server
    where
        F: FnOnce() -> Result<ModelRuntime> + Send + 'static,
    {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            dead: AtomicBool::new(false),
        });
        let s2 = Arc::clone(&shared);
        let worker = thread::spawn(move || {
            let result = match factory() {
                Ok(mrt) => batcher_loop(&s2, mrt, params),
                Err(e) => Err(e),
            };
            // Dead first, then drain: submit checks the flag under the
            // queue lock, so a racing request either sees the flag or its
            // queued entry is dropped here and the receiver disconnects.
            s2.dead.store(true, Ordering::SeqCst);
            s2.queue.lock().unwrap().clear();
            result
        });
        Server { shared, worker: Some(worker), next_id: Mutex::new(1) }
    }

    /// Serve from resident packed weights on the native backend: prefill
    /// and every decode step compute directly on RaBitQ codes via
    /// `qgemm` — no AOT artifacts, no dense weight reads, zero
    /// dequantization on the request path.
    pub fn start_native_packed(
        manifest: Manifest,
        params: ModelParams,
        packed: PackedLayers,
    ) -> Server {
        Server::start(
            move || {
                let mut mrt = ModelRuntime::native(manifest)?;
                mrt.attach_packed(packed)?;
                Ok(mrt)
            },
            params,
        )
    }

    /// Submit a request; returns the request id and a receiver for its
    /// [`Completion`].
    ///
    /// A `max_new_tokens` of 0 completes immediately with an empty token
    /// list (no model work, not counted in [`ServerStats`]).
    ///
    /// # Errors
    ///
    /// Fails once the server stopped accepting work: after
    /// [`Server::shutdown`] began, or after the batcher thread exited
    /// (e.g. its runtime factory failed). Without this check the request
    /// would queue into a dead batcher and its receiver would block
    /// forever.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        temperature: f32,
        seed: u64,
    ) -> Result<(u64, mpsc::Receiver<Completion>)> {
        let id = {
            let mut g = self.next_id.lock().unwrap();
            let id = *g;
            *g += 1;
            id
        };
        let (tx, rx) = mpsc::channel();
        if max_new_tokens == 0 {
            let _ = tx.send(Completion { id, tokens: Vec::new(), latency_secs: 0.0, steps: 0 });
            return Ok((id, rx));
        }
        let act = Active {
            req: Request { id, prompt, max_new_tokens, temperature, seed },
            generated: Vec::new(),
            submitted: Instant::now(),
            steps: 0,
            done_tx: tx,
        };
        {
            let mut q = self.shared.queue.lock().unwrap();
            anyhow::ensure!(
                !self.shared.dead.load(Ordering::SeqCst)
                    && !*self.shared.shutdown.lock().unwrap(),
                "server is not accepting requests (shut down or batcher exited)"
            );
            q.push_back(act);
        }
        self.shared.cv.notify_one();
        Ok((id, rx))
    }

    /// True while the batcher thread is alive and accepting submissions.
    pub fn is_running(&self) -> bool {
        !self.shared.dead.load(Ordering::SeqCst)
    }

    /// Stop the batcher (after draining in-flight and queued work) and
    /// collect stats.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        {
            let mut s = self.shared.shutdown.lock().unwrap();
            *s = true;
        }
        self.shared.cv.notify_all();
        let handle = self.worker.take().expect("not yet shut down");
        handle.join().map_err(|_| anyhow::anyhow!("batcher panicked"))?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.worker.is_some() {
            {
                let mut s = self.shared.shutdown.lock().unwrap();
                *s = true;
            }
            self.shared.cv.notify_all();
            if let Some(h) = self.worker.take() {
                let _ = h.join();
            }
        }
    }
}

/// The request's full context (prompt + generated so far), truncated to
/// the trailing `seq` tokens — exactly the window the recompute reference
/// evaluates. Empty prompts fall back to a single `0` token so prefill
/// always has at least one position.
fn context_window(act: &Active, seq: usize) -> Vec<i32> {
    let mut ctx: Vec<i32> = act
        .req
        .prompt
        .iter()
        .chain(act.generated.iter())
        .copied()
        .collect();
    if ctx.is_empty() {
        ctx.push(0);
    }
    if ctx.len() > seq {
        ctx.drain(..ctx.len() - seq);
    }
    ctx
}

/// Sample one token from `logits` for `act`, then either complete the
/// request (send the [`Completion`], free the cache lane, return `None`)
/// or hand the still-active request back.
fn settle(
    mut act: Active,
    logits: &[f32],
    cache: &mut KvCache,
    slot: usize,
    stats: &mut ServerStats,
) -> Option<Active> {
    let tok = softmax_sample(logits, act.req.temperature, act.req.seed, act.steps);
    act.generated.push(tok);
    act.steps += 1;
    stats.tokens_generated += 1;
    if act.generated.len() >= act.req.max_new_tokens {
        let latency = act.submitted.elapsed().as_secs_f64();
        stats.latencies.push(latency);
        stats.completions += 1;
        let _ = act.done_tx.send(Completion {
            id: act.req.id,
            tokens: act.generated,
            latency_secs: latency,
            steps: act.steps,
        });
        cache.reset(slot);
        None
    } else {
        Some(act)
    }
}

fn batcher_loop(
    shared: &Shared,
    mrt: ModelRuntime,
    params: ModelParams,
) -> Result<ServerStats> {
    let m = &mrt.manifest;
    let (batch, seq, vocab) = (m.eval_batch, m.seq_len, m.vocab);
    let mut cache = mrt.new_kv_cache(batch);
    let mut lanes: Vec<Option<Active>> = (0..batch).map(|_| None).collect();
    let mut stats = ServerStats::default();
    let start = Instant::now();

    loop {
        // ---- admit queued requests into free lanes: one prefill each,
        // which also yields the request's first token
        for slot in 0..batch {
            if lanes[slot].is_some() {
                continue;
            }
            let Some(act) = shared.queue.lock().unwrap().pop_front() else {
                break;
            };
            let window = context_window(&act, seq);
            let logits = mrt.prefill(&params, &mut cache, slot, &window)?;
            stats.batch_steps += 1;
            stats.total_rows += 1;
            stats.prefill_tokens += window.len();
            lanes[slot] = settle(act, &logits, &mut cache, slot, &mut stats);
        }

        // ---- idle: wait for work or shutdown
        if lanes.iter().all(|l| l.is_none()) {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if !q.is_empty() {
                    break;
                }
                if *shared.shutdown.lock().unwrap() {
                    stats.wall_secs = start.elapsed().as_secs_f64();
                    return Ok(stats);
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, std::time::Duration::from_millis(20))
                    .unwrap();
                q = guard;
            }
            continue;
        }

        // ---- full windows slide via re-prefill (absolute position
        // embeddings re-position every token, so the cached rows are
        // stale by construction; in-window lanes stay on the fast path)
        for slot in 0..batch {
            let Some(act) = lanes[slot].take() else { continue };
            if !cache.is_full(slot) {
                lanes[slot] = Some(act);
                continue;
            }
            let window = context_window(&act, seq);
            let logits = mrt.prefill(&params, &mut cache, slot, &window)?;
            stats.batch_steps += 1;
            stats.total_rows += 1;
            stats.prefill_tokens += window.len();
            stats.window_slides += 1;
            lanes[slot] = settle(act, &logits, &mut cache, slot, &mut stats);
        }

        // ---- fixed-shape batched decode over the remaining active lanes
        let decode: Vec<usize> = (0..batch)
            .filter(|&s| lanes[s].is_some() && !cache.is_full(s))
            .collect();
        if !decode.is_empty() {
            let tokens: Vec<i32> = decode
                .iter()
                .map(|&s| *lanes[s].as_ref().unwrap().generated.last().unwrap())
                .collect();
            let rows = mrt.decode_step(&params, &mut cache, &decode, &tokens)?;
            stats.batch_steps += 1;
            stats.total_rows += decode.len();
            stats.decode_steps += 1;
            for (i, &slot) in decode.iter().enumerate() {
                let act = lanes[slot].take().expect("decode lane is active");
                let logits = &rows[i * vocab..(i + 1) * vocab];
                lanes[slot] = settle(act, logits, &mut cache, slot, &mut stats);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthetic_manifest;
    use crate::quant::{LayerCalib, TrickConfig};
    use crate::runtime::{native_init, PackedLayers};

    #[test]
    fn greedy_sampling_is_argmax() {
        let logits = vec![0.1f32, 2.0, -1.0, 1.9];
        assert_eq!(softmax_sample(&logits, 0.0, 0, 0), 1);
    }

    #[test]
    fn temperature_sampling_in_range_and_seeded() {
        let logits = vec![0.0f32; 16];
        let a = softmax_sample(&logits, 1.0, 42, 3);
        let b = softmax_sample(&logits, 1.0, 42, 3);
        assert_eq!(a, b);
        assert!((0..16).contains(&a));
    }

    fn packed_fixture(
        name: &str,
        seq_len: usize,
        eval_batch: usize,
        seed: u64,
    ) -> (Manifest, ModelParams, PackedLayers) {
        let manifest = synthetic_manifest(name, 32, 1, 2, 64, seq_len, 256, eval_batch);
        let params = native_init(&manifest, seed);
        let stats: Vec<LayerCalib> =
            manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; manifest.linears.len()];
        let packed = PackedLayers::quantize(
            &manifest, &params, &bits, &stats, &TrickConfig::none(), 1, 1,
        )
        .unwrap();
        (manifest, params, packed)
    }

    #[test]
    fn native_packed_server_generates_tokens() {
        let (manifest, params, packed) = packed_fixture("serve-native", 8, 2, 17);
        let server = Server::start_native_packed(manifest, params, packed);
        let (_, rx) = server.submit(vec![1, 2, 3], 4, 0.0, 0).unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 4);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.completions, 1);
        assert_eq!(stats.tokens_generated, 4);
        // 1 admission prefill + 3 decode rounds (no slides: 3 + 4 <= 8)
        assert_eq!(stats.prefill_tokens, 3);
        assert_eq!(stats.window_slides, 0);
        assert!(stats.decode_steps >= 3);
    }

    #[test]
    fn kv_server_slides_window_past_context() {
        // seq_len 8, 20 generated tokens: the lane must slide repeatedly
        let (manifest, params, packed) = packed_fixture("serve-slide", 8, 1, 23);
        let server = Server::start_native_packed(manifest, params, packed);
        let (_, rx) = server.submit(vec![9, 8, 7], 20, 0.7, 5).unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 20);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.completions, 1);
        assert_eq!(stats.tokens_generated, 20);
        assert!(
            stats.window_slides >= 10,
            "window_slides {} — beyond-context generation must slide",
            stats.window_slides
        );
    }

    #[test]
    fn zero_token_request_completes_empty() {
        let (manifest, params, packed) = packed_fixture("serve-zero", 8, 1, 31);
        let server = Server::start_native_packed(manifest, params, packed);
        let (_, rx) = server.submit(vec![1, 2], 0, 0.0, 0).unwrap();
        let c = rx.recv().unwrap();
        assert!(c.tokens.is_empty(), "asked for zero tokens, got {:?}", c.tokens);
        assert_eq!(c.steps, 0);
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.tokens_generated, 0);
    }

    #[test]
    fn empty_prompt_is_served() {
        let (manifest, params, packed) = packed_fixture("serve-empty", 8, 1, 29);
        let server = Server::start_native_packed(manifest, params, packed);
        let (_, rx) = server.submit(Vec::new(), 3, 0.0, 0).unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 3);
        server.shutdown().unwrap();
    }

    #[test]
    fn submit_into_dead_batcher_errors_not_hangs() {
        let manifest = synthetic_manifest("serve-dead", 16, 1, 2, 32, 8, 64, 1);
        let params = native_init(&manifest, 1);
        let server = Server::start(|| anyhow::bail!("factory exploded"), params);
        let mut waited = 0;
        while server.is_running() && waited < 500 {
            std::thread::sleep(std::time::Duration::from_millis(10));
            waited += 1;
        }
        assert!(!server.is_running(), "worker should have died");
        assert!(server.submit(vec![1], 3, 0.0, 0).is_err());
        // shutdown surfaces the factory error instead of stats
        assert!(server.shutdown().is_err());
    }

    #[test]
    fn receivers_disconnect_when_batcher_dies() {
        let manifest = synthetic_manifest("serve-late", 16, 1, 2, 32, 8, 64, 1);
        let params = native_init(&manifest, 2);
        let server = Server::start(
            || {
                std::thread::sleep(std::time::Duration::from_millis(50));
                anyhow::bail!("late failure")
            },
            params,
        );
        // this submit may race the death either way; both outcomes are
        // lifecycle-correct — an error, or a receiver that disconnects
        if let Ok((_, rx)) = server.submit(vec![1], 2, 0.0, 0) {
            assert!(rx.recv().is_err(), "receiver must disconnect, not hang");
        }
        assert!(server.shutdown().is_err());
    }

    #[test]
    fn stats_math() {
        let s = ServerStats {
            completions: 2,
            batch_steps: 4,
            total_rows: 12,
            tokens_generated: 40,
            latencies: vec![0.1, 0.2],
            wall_secs: 2.0,
            ..Default::default()
        };
        assert!((s.mean_batch_occupancy(4) - 0.75).abs() < 1e-12);
        assert!((s.throughput_tok_s() - 20.0).abs() < 1e-12);
        assert!(s.p95_latency() >= s.p50_latency());
    }
}
