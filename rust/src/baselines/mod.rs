//! Baseline PTQ methods for the paper-table comparisons (Tables 1 & 4).
//!
//! * [`rtn_quantize`] — round-to-nearest with per-group asymmetric
//!   min/max grids (the "GPTQ/AWQ/OmniQuant with grouping 128" substrate).
//! * [`gptq_quantize`] — GPTQ (Frantar et al. 2023): OBQ column ordering
//!   with Hessian-weighted error compensation, Hessian `H = X^T X + λI`
//!   from the calibration capture.
//! * [`awq_quantize`] — AWQ-lite (Lin et al. 2024): activation-aware
//!   per-input-channel scaling before RTN.
//! * [`easyquant_quantize`] — EasyQuant-analog (Tang et al. 2024):
//!   data-free RTN keeping the top weight outliers full precision.
//!
//! Each returns the reconstructed effective weight plus an honest
//! average-bits figure including side payloads (scales, zeros, outliers) —
//! the "+" in the paper's "2+/3+/4+ bits" rows.

use anyhow::Result;

use crate::tensor::{spd_inverse, Matrix};

/// Result of a baseline quantization of one layer.
#[derive(Clone, Debug)]
pub struct BaselineResult {
    pub w_hat: Matrix,
    /// Average stored bits per parameter (codes + side payloads).
    pub avg_bits: f64,
}

/// Per-group asymmetric uniform grid along the input (row) dimension.
/// Groups of `group` consecutive rows share one (scale, zero) pair per
/// column; fp16 scale+zero => 32 bits per group per column of overhead.
pub fn rtn_quantize(w: &Matrix, bits: u8, group: usize) -> BaselineResult {
    assert!((1..=8).contains(&bits));
    let (d, c) = (w.rows, w.cols);
    let levels = ((1u32 << bits) - 1) as f32;
    let mut w_hat = Matrix::zeros(d, c);
    let group = group.max(1).min(d);
    let n_groups = d.div_ceil(group);

    for j in 0..c {
        for gidx in 0..n_groups {
            let lo = gidx * group;
            let hi = ((gidx + 1) * group).min(d);
            let mut min = f32::INFINITY;
            let mut max = f32::NEG_INFINITY;
            for i in lo..hi {
                let v = w.at(i, j);
                min = min.min(v);
                max = max.max(v);
            }
            let scale = if max > min { (max - min) / levels } else { 1.0 };
            for i in lo..hi {
                let q = ((w.at(i, j) - min) / scale).round().clamp(0.0, levels);
                *w_hat.at_mut(i, j) = min + q * scale;
            }
        }
    }
    let side_bits = n_groups * c * 32; // fp16 scale + fp16 zero per group/col
    BaselineResult {
        w_hat,
        avg_bits: bits as f64 + side_bits as f64 / (d * c) as f64,
    }
}

/// GPTQ: column-by-column (along the input dim) quantization with error
/// compensation weighted by the inverse Hessian `(X^T X + λI)^-1`.
///
/// `hessian` is the layer's d x d calibration Gram matrix X^T X. Grouped
/// RTN grids (size `group`) supply the quantization lattice, exactly as in
/// the reference implementation's `groupsize=128` configuration.
pub fn gptq_quantize(
    w: &Matrix,
    bits: u8,
    group: usize,
    hessian: &Matrix,
) -> Result<BaselineResult> {
    let (d, c) = (w.rows, w.cols);
    anyhow::ensure!(hessian.rows == d && hessian.cols == d, "hessian shape");
    let levels = ((1u32 << bits) - 1) as f32;
    let group = group.max(1).min(d);

    // damped Hessian inverse
    let mut h = hessian.clone();
    let mean_diag: f64 =
        (0..d).map(|i| h.at(i, i) as f64).sum::<f64>() / d as f64;
    let damp = (0.01 * mean_diag).max(1e-8) as f32;
    for i in 0..d {
        *h.at_mut(i, i) += damp;
    }
    let hinv = spd_inverse(&h)
        .ok_or_else(|| anyhow::anyhow!("GPTQ Hessian not SPD after damping"))?;

    // Work on W^T rows? Keep W (d x c); process input dims i = 0..d in
    // order, quantizing row i against per-group grids and propagating the
    // error to the not-yet-quantized rows k > i scaled by Hinv[k,i]/Hinv[i,i].
    let mut wk = w.clone(); // working copy, rows >= i hold compensated values
    let mut w_hat = Matrix::zeros(d, c);

    // Precompute per-group min/max grids from the *original* weights
    // (re-deriving per group keeps the lattice stable, as in GPTQ).
    let n_groups = d.div_ceil(group);
    let mut gmin = vec![vec![0f32; c]; n_groups];
    let mut gscale = vec![vec![1f32; c]; n_groups];
    for gidx in 0..n_groups {
        let lo = gidx * group;
        let hi = ((gidx + 1) * group).min(d);
        for j in 0..c {
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for i in lo..hi {
                let v = w.at(i, j);
                mn = mn.min(v);
                mx = mx.max(v);
            }
            gmin[gidx][j] = mn;
            gscale[gidx][j] = if mx > mn { (mx - mn) / levels } else { 1.0 };
        }
    }

    for i in 0..d {
        let gidx = i / group;
        let hii = hinv.at(i, i).max(1e-12);
        // quantize row i
        let mut err = vec![0f32; c];
        for j in 0..c {
            let v = wk.at(i, j);
            let q = ((v - gmin[gidx][j]) / gscale[gidx][j])
                .round()
                .clamp(0.0, levels);
            let deq = gmin[gidx][j] + q * gscale[gidx][j];
            *w_hat.at_mut(i, j) = deq;
            err[j] = (v - deq) / hii;
        }
        // propagate error to remaining rows
        for k in (i + 1)..d {
            let factor = hinv.at(k, i);
            if factor == 0.0 {
                continue;
            }
            let row = wk.row_mut(k);
            for j in 0..c {
                row[j] -= factor * err[j];
            }
        }
    }

    let side_bits = n_groups * c * 32;
    Ok(BaselineResult {
        w_hat,
        avg_bits: bits as f64 + side_bits as f64 / (d * c) as f64,
    })
}

/// AWQ-lite: per-input-channel scales `s_i = (mean |X_i|)^alpha` protect
/// salient channels; quantize diag(s) W with RTN, reconstruct with
/// diag(1/s). `act_mean_abs` is the calibration per-channel mean |X|.
pub fn awq_quantize(
    w: &Matrix,
    bits: u8,
    group: usize,
    act_mean_abs: &[f64],
    alpha: f64,
) -> BaselineResult {
    let (d, c) = (w.rows, w.cols);
    assert_eq!(act_mean_abs.len(), d);
    let mean_act: f64 =
        act_mean_abs.iter().sum::<f64>() / d as f64;
    let scales: Vec<f32> = act_mean_abs
        .iter()
        .map(|&a| {
            let base = if mean_act > 0.0 { (a / mean_act).max(1e-4) } else { 1.0 };
            (base.powf(alpha)) as f32
        })
        .collect();
    let mut ws = w.clone();
    for i in 0..d {
        let s = scales[i];
        for v in ws.row_mut(i) {
            *v *= s;
        }
    }
    let mut res = rtn_quantize(&ws, bits, group);
    for i in 0..d {
        let s = scales[i];
        for v in res.w_hat.row_mut(i) {
            *v /= s;
        }
    }
    // store one fp16 scale per input channel
    res.avg_bits += (d * 16) as f64 / (d * c) as f64;
    res
}

/// EasyQuant-analog: data-free — RTN plus keeping the top `frac` largest-
/// magnitude weights per column in full precision (stored sparse as
/// (row index, fp32 value)).
pub fn easyquant_quantize(w: &Matrix, bits: u8, group: usize, frac: f64) -> BaselineResult {
    let (d, c) = (w.rows, w.cols);
    let mut res = rtn_quantize(w, bits, group);
    let k = ((frac * d as f64).ceil() as usize).min(d);
    if k == 0 {
        return res;
    }
    for j in 0..c {
        // top-k |w| rows in this column kept exact
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by(|&a, &b| {
            w.at(b, j)
                .abs()
                .partial_cmp(&w.at(a, j).abs())
                .unwrap()
        });
        for &i in order[..k].iter() {
            *res.w_hat.at_mut(i, j) = w.at(i, j);
        }
    }
    res.avg_bits += (k * c * (32 + 32)) as f64 / (d * c) as f64;
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_w(d: usize, c: usize, seed: u64) -> Matrix {
        Matrix::from_vec(d, c, Rng::new(seed).gaussian_vec(d * c))
    }

    fn gram(x: &Matrix) -> Matrix {
        x.transpose().matmul(x)
    }

    #[test]
    fn rtn_error_decays_with_bits() {
        let w = random_w(128, 32, 1);
        let mut prev = f64::INFINITY;
        for bits in [2u8, 3, 4, 6, 8] {
            let r = rtn_quantize(&w, bits, 64);
            let err = r.w_hat.rel_err(&w);
            assert!(err < prev, "bits={bits}");
            prev = err;
        }
    }

    #[test]
    fn rtn_respects_grid_bounds() {
        let w = random_w(64, 8, 2);
        let r = rtn_quantize(&w, 4, 32);
        // every reconstructed value must lie within its group's [min, max]
        for j in 0..8 {
            for g in 0..2 {
                let lo = g * 32;
                let hi = lo + 32;
                let mn = (lo..hi).map(|i| w.at(i, j)).fold(f32::INFINITY, f32::min);
                let mx = (lo..hi).map(|i| w.at(i, j)).fold(f32::NEG_INFINITY, f32::max);
                for i in lo..hi {
                    let v = r.w_hat.at(i, j);
                    assert!(v >= mn - 1e-4 && v <= mx + 1e-4);
                }
            }
        }
    }

    #[test]
    fn rtn_avg_bits_accounting() {
        let r = rtn_quantize(&random_w(128, 128, 3), 3, 128);
        // one group: 32 extra bits per column over 128 rows ~ 0.25
        assert!((r.avg_bits - 3.25).abs() < 1e-9, "{}", r.avg_bits);
    }

    #[test]
    fn gptq_beats_rtn_under_calibration_distribution() {
        // GPTQ minimizes ||X(W - W_hat)||_F, so compare in that metric.
        let d = 64;
        let w = random_w(d, 32, 4);
        let x = random_w(256, d, 5);
        let h = gram(&x);
        let gptq = gptq_quantize(&w, 3, 32, &h).unwrap();
        let rtn = rtn_quantize(&w, 3, 32);
        let err_gptq = x.matmul(&gptq.w_hat).rel_err(&x.matmul(&w));
        let err_rtn = x.matmul(&rtn.w_hat).rel_err(&x.matmul(&w));
        assert!(
            err_gptq < err_rtn,
            "gptq {err_gptq} should beat rtn {err_rtn}"
        );
    }

    #[test]
    fn gptq_shape_mismatch_errors() {
        let w = random_w(16, 4, 6);
        let h = Matrix::eye(8);
        assert!(gptq_quantize(&w, 3, 16, &h).is_err());
    }

    #[test]
    fn gptq_identity_hessian_close_to_rtn() {
        // with H = I there is no cross-correlation to exploit; error should
        // be in the same ballpark as plain RTN
        let w = random_w(32, 16, 7);
        let h = Matrix::eye(32);
        let gptq = gptq_quantize(&w, 4, 32, &h).unwrap();
        let rtn = rtn_quantize(&w, 4, 32);
        let a = gptq.w_hat.rel_err(&w);
        let b = rtn.w_hat.rel_err(&w);
        assert!(a < b * 1.5 + 1e-6, "{a} vs {b}");
    }

    #[test]
    fn awq_protects_salient_channels() {
        let d = 64;
        let w = random_w(d, 32, 8);
        // channel 5 has huge activations
        let mut act = vec![1.0f64; d];
        act[5] = 50.0;
        let awq = awq_quantize(&w, 2, 64, &act, 0.5);
        let rtn = rtn_quantize(&w, 2, 64);
        let row_err = |wh: &Matrix, i: usize| -> f64 {
            (0..32)
                .map(|j| ((wh.at(i, j) - w.at(i, j)) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        assert!(
            row_err(&awq.w_hat, 5) < row_err(&rtn.w_hat, 5),
            "salient row should quantize finer under AWQ"
        );
    }

    #[test]
    fn easyquant_outliers_exact() {
        let mut w = random_w(64, 8, 9);
        *w.at_mut(17, 3) = 40.0; // a huge outlier weight
        let r = easyquant_quantize(&w, 2, 64, 0.02);
        assert_eq!(r.w_hat.at(17, 3), 40.0);
        assert!(r.avg_bits > 2.0);
    }

    #[test]
    fn easyquant_zero_frac_is_rtn() {
        let w = random_w(32, 8, 10);
        let a = easyquant_quantize(&w, 3, 32, 0.0);
        let b = rtn_quantize(&w, 3, 32);
        assert_eq!(a.w_hat.data, b.w_hat.data);
        assert!((a.avg_bits - b.avg_bits).abs() < 1e-12);
    }
}
