//! AllocateBits: optimal per-layer bit-width allocation (paper §4, Alg. 4).
//!
//! Minimize `Σ_k α_k 2^{-b_k}` subject to `Σ_k b_k m_k <= R`, `b_k ∈ B`,
//! solved exactly by dynamic programming after the divide-by-GCD reduction
//! `g = gcd(m_1, …, m_L, R)` (paper eq. 5). Hidden sizes that are powers
//! of two (which the paper advocates, and our models use) make `g` large,
//! shrinking the DP budget axis from ~10^7 to ~10^2 states.
//!
//! `solve` runs the GCD-reduced DP; `solve_no_gcd_reduction` is the
//! ablation comparator for `benches/ablate_gcd.rs` (the paper's
//! "millions of times slower without it" claim).
#![deny(missing_docs)]

use anyhow::{bail, Result};

/// One bit-allocation problem instance.
#[derive(Clone, Debug)]
pub struct AllocProblem {
    /// Per-layer sensitivity coefficients α_k (paper eq. 23).
    pub alphas: Vec<f64>,
    /// Per-layer parameter counts m_k.
    pub m: Vec<usize>,
    /// Candidate bit-widths B (e.g. 1..=8).
    pub bit_choices: Vec<u8>,
    /// Total bit budget R.
    pub budget: u64,
}

/// Result of the allocation.
#[derive(Clone, Debug, PartialEq)]
pub struct Allocation {
    /// Chosen bit-width per layer, in problem order.
    pub bits: Vec<u8>,
    /// Objective value Σ α_k 2^{-b_k}.
    pub cost: f64,
    /// Σ b_k m_k actually used.
    pub used_bits: u64,
    /// The gcd g used in the reduction.
    pub g: u64,
    /// Number of DP states touched (for the ablation bench).
    pub dp_states: u64,
}

/// Euclid's greatest common divisor (`gcd(0, b) = b`).
pub fn gcd(a: u64, b: u64) -> u64 {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl AllocProblem {
    /// Budget from a target average bits-per-parameter.
    pub fn budget_for_avg_bits(m: &[usize], avg_bits: f64) -> u64 {
        let total: u64 = m.iter().map(|&x| x as u64).sum();
        (avg_bits * total as f64).floor() as u64
    }

    /// Reject malformed instances (arity mismatches, empty or
    /// out-of-range bit choices, non-finite sensitivities, or a budget
    /// below the all-minimum-bits floor). Called by every solver.
    pub fn validate(&self) -> Result<()> {
        let l = self.alphas.len();
        if l == 0 || self.m.len() != l {
            bail!("alphas/m length mismatch ({} vs {})", l, self.m.len());
        }
        if self.bit_choices.is_empty() {
            bail!("empty bit-width candidate set");
        }
        if self.bit_choices.iter().any(|&b| b == 0 || b > 16) {
            bail!("bit choices must be in 1..=16");
        }
        if self.alphas.iter().any(|&a| !a.is_finite() || a < 0.0) {
            bail!("alphas must be finite and non-negative");
        }
        let min_b = *self.bit_choices.iter().min().unwrap() as u64;
        let min_need: u64 = self.m.iter().map(|&mk| mk as u64 * min_b).sum();
        if min_need > self.budget {
            bail!(
                "infeasible: minimum need {} bits > budget {} (avg {:.2} bpp)",
                min_need,
                self.budget,
                self.budget as f64 / self.m.iter().map(|&x| x as f64).sum::<f64>()
            );
        }
        Ok(())
    }

    /// Solve with the paper's divide-by-GCD reduction (Alg. 4).
    ///
    /// The budget is first rounded down to a multiple of gcd(m_1..m_L):
    /// an arbitrary R makes g = gcd(m…, R) collapse to ~1 and forfeits the
    /// reduction, while the rounding forfeits < gcd(m) bits out of
    /// millions (< 0.01 avg bits on every model here).
    ///
    /// # Examples
    ///
    /// ```
    /// use raana::allocate::AllocProblem;
    ///
    /// // two equal-sized layers, the first 8x more quantization-sensitive
    /// let p = AllocProblem {
    ///     alphas: vec![8.0, 1.0],
    ///     m: vec![64, 64],
    ///     bit_choices: vec![2, 4, 8],
    ///     budget: AllocProblem::budget_for_avg_bits(&[64, 64], 6.0),
    /// };
    /// let a = p.solve().unwrap();
    /// assert_eq!(a.bits, vec![8, 4]); // sensitive layer gets the bits
    /// assert!(a.used_bits <= p.budget);
    /// ```
    pub fn solve(&self) -> Result<Allocation> {
        let mut g_m = 0u64;
        for &mk in &self.m {
            g_m = gcd(g_m, mk as u64);
        }
        let g_m = g_m.max(1);
        let mut p = self.clone();
        p.budget -= p.budget % g_m;
        p.solve_with_g(p.reduction_gcd())
    }

    /// Ablation: identical DP with g forced to 1 (paper §4.1 claims this
    /// is millions of times slower on LLaMA-scale m_k).
    pub fn solve_no_gcd_reduction(&self) -> Result<Allocation> {
        self.solve_with_g(1)
    }

    /// g = gcd(m_1, ..., m_L, R).
    pub fn reduction_gcd(&self) -> u64 {
        let mut g = self.budget;
        for &mk in &self.m {
            g = gcd(g, mk as u64);
        }
        g.max(1)
    }

    fn solve_with_g(self: &AllocProblem, g: u64) -> Result<Allocation> {
        self.validate()?;
        let l = self.alphas.len();
        let cap = (self.budget / g) as usize;

        // f[r] = min cost using layers processed so far with <= r reduced
        // budget; choice[k * (cap+1) + r] = index into bit_choices.
        let mut f = vec![f64::INFINITY; cap + 1];
        let mut next = vec![f64::INFINITY; cap + 1];
        let mut choice = vec![u8::MAX; l * (cap + 1)];
        f[0] = 0.0;
        let mut dp_states: u64 = 0;

        for k in 0..l {
            for x in next.iter_mut() {
                *x = f64::INFINITY;
            }
            let mk = self.m[k] as u64;
            for (bi, &b) in self.bit_choices.iter().enumerate() {
                let w = (mk * b as u64) / g; // m_k and budget divisible by g
                let cost = self.alphas[k] * 2f64.powi(-(b as i32));
                if w as usize > cap {
                    continue;
                }
                for r in 0..=(cap - w as usize) {
                    dp_states += 1; // loop work, finite or not — this is
                                    // exactly what the GCD trick shrinks
                    let base = f[r];
                    if !base.is_finite() {
                        continue;
                    }
                    let cand = base + cost;
                    let slot = r + w as usize;
                    if cand < next[slot] {
                        next[slot] = cand;
                        choice[k * (cap + 1) + slot] = bi as u8;
                    }
                }
            }
            // prefix-min so f[r] means "<= r budget used"
            std::mem::swap(&mut f, &mut next);
            // NOTE: we keep f as exact-usage table and take min at the end;
            // but reconstruction needs exact r, so no prefix-min here.
        }

        // best final state
        let (mut best_r, mut best_cost) = (usize::MAX, f64::INFINITY);
        for (r, &c) in f.iter().enumerate() {
            if c < best_cost {
                best_cost = c;
                best_r = r;
            }
        }
        if best_r == usize::MAX {
            bail!("DP found no feasible allocation");
        }

        // Walk parent pointers backwards.
        let mut bits = vec![0u8; l];
        let mut r = best_r;
        for k in (0..l).rev() {
            let bi = choice[k * (cap + 1) + r];
            if bi == u8::MAX {
                bail!("DP reconstruction failed at layer {k}");
            }
            let b = self.bit_choices[bi as usize];
            bits[k] = b;
            r -= ((self.m[k] as u64 * b as u64) / g) as usize;
        }

        let used_bits: u64 = bits
            .iter()
            .zip(&self.m)
            .map(|(&b, &mk)| b as u64 * mk as u64)
            .sum();
        Ok(Allocation { bits, cost: best_cost, used_bits, g, dp_states })
    }

    /// Exhaustive solver for tiny instances (test oracle).
    pub fn solve_brute_force(&self) -> Result<Allocation> {
        self.validate()?;
        let l = self.alphas.len();
        let nb = self.bit_choices.len();
        let mut best: Option<(f64, Vec<u8>, u64)> = None;
        let mut idx = vec![0usize; l];
        loop {
            let bits: Vec<u8> = idx.iter().map(|&i| self.bit_choices[i]).collect();
            let used: u64 = bits
                .iter()
                .zip(&self.m)
                .map(|(&b, &mk)| b as u64 * mk as u64)
                .sum();
            if used <= self.budget {
                let cost: f64 = bits
                    .iter()
                    .zip(&self.alphas)
                    .map(|(&b, &a)| a * 2f64.powi(-(b as i32)))
                    .sum();
                if best.as_ref().map(|(c, _, _)| cost < *c).unwrap_or(true) {
                    best = Some((cost, bits, used));
                }
            }
            // increment mixed-radix counter
            let mut carry = true;
            for slot in idx.iter_mut() {
                if carry {
                    *slot += 1;
                    if *slot == nb {
                        *slot = 0;
                    } else {
                        carry = false;
                    }
                }
            }
            if carry {
                break;
            }
        }
        let (cost, bits, used_bits) =
            best.ok_or_else(|| anyhow::anyhow!("no feasible brute-force solution"))?;
        Ok(Allocation { bits, cost, used_bits, g: 1, dp_states: 0 })
    }
}

/// Compute α_k from the calibration quantities (paper eq. 23):
/// `α_k = (1/sqrt(d_k)) * ||dL/dH_k||_F * ||X_k||_F * ||W_k||_F`.
pub fn alpha_from_calib(d_k: usize, gnorm: f64, xnorm: f64, wnorm: f64) -> f64 {
    gnorm * xnorm * wnorm / (d_k as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn problem(l: usize, seed: u64, avg_bits: f64) -> AllocProblem {
        let mut rng = Rng::new(seed);
        let m: Vec<usize> = (0..l)
            .map(|_| 64 * (1 + rng.below(8)))
            .collect();
        let alphas: Vec<f64> = (0..l).map(|_| rng.next_f64() * 10.0 + 0.01).collect();
        let budget = AllocProblem::budget_for_avg_bits(&m, avg_bits);
        AllocProblem { alphas, m, bit_choices: vec![1, 2, 3, 4, 6, 8], budget }
    }

    #[test]
    fn gcd_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(gcd(7, 13), 1);
        assert_eq!(gcd(0, 5), 5);
        assert_eq!(gcd(1024, 65536), 1024);
    }

    #[test]
    fn respects_budget_and_choices() {
        let p = problem(20, 1, 3.1);
        let sol = p.solve().unwrap();
        assert!(sol.used_bits <= p.budget);
        assert!(sol.bits.iter().all(|b| p.bit_choices.contains(b)));
        assert_eq!(sol.bits.len(), 20);
    }

    #[test]
    fn matches_brute_force_on_small_instances() {
        for seed in 0..8u64 {
            let mut p = problem(5, seed, 2.5);
            p.bit_choices = vec![2, 3, 4];
            let dp = p.solve().unwrap();
            let bf = p.solve_brute_force().unwrap();
            assert!(
                (dp.cost - bf.cost).abs() < 1e-9,
                "seed={seed}: dp {} vs bf {}",
                dp.cost,
                bf.cost
            );
        }
    }

    #[test]
    fn no_gcd_matches_gcd_solution_cost() {
        let p = problem(8, 3, 3.0);
        let a = p.solve().unwrap();
        let b = p.solve_no_gcd_reduction().unwrap();
        assert!((a.cost - b.cost).abs() < 1e-9);
        assert!(b.dp_states >= a.dp_states);
    }

    #[test]
    fn gcd_reduction_shrinks_state_count() {
        // power-of-2 m_k -> large g -> far fewer DP states
        let m = vec![65536usize; 12];
        let alphas = vec![1.0; 12];
        let budget = AllocProblem::budget_for_avg_bits(&m, 3.0);
        let p = AllocProblem { alphas, m, bit_choices: vec![2, 3, 4], budget };
        let with = p.solve().unwrap();
        let without = p.solve_no_gcd_reduction().unwrap();
        assert_eq!(with.g, 65536);
        assert!(without.dp_states > 1000 * with.dp_states,
                "{} vs {}", without.dp_states, with.dp_states);
        assert!((with.cost - without.cost).abs() < 1e-9);
    }

    #[test]
    fn sensitive_layers_get_more_bits() {
        let m = vec![1024usize; 4];
        let alphas = vec![100.0, 1.0, 1.0, 100.0];
        let budget = AllocProblem::budget_for_avg_bits(&m, 3.0);
        let p = AllocProblem { alphas, m, bit_choices: vec![1, 2, 3, 4, 5, 6], budget };
        let sol = p.solve().unwrap();
        assert!(sol.bits[0] > sol.bits[1]);
        assert!(sol.bits[3] > sol.bits[2]);
    }

    #[test]
    fn uniform_alphas_give_near_uniform_bits() {
        let m = vec![2048usize; 6];
        let alphas = vec![1.0; 6];
        let budget = AllocProblem::budget_for_avg_bits(&m, 4.0);
        let p = AllocProblem { alphas, m, bit_choices: (1..=8).collect(), budget };
        let sol = p.solve().unwrap();
        let min = *sol.bits.iter().min().unwrap();
        let max = *sol.bits.iter().max().unwrap();
        assert!(max - min <= 1, "{:?}", sol.bits);
        assert!((sol.used_bits as f64) >= 0.95 * p.budget as f64);
    }

    #[test]
    fn infeasible_budget_errors() {
        let p = AllocProblem {
            alphas: vec![1.0, 1.0],
            m: vec![100, 100],
            bit_choices: vec![2, 3],
            budget: 100, // needs >= 400
        };
        assert!(p.solve().is_err());
    }

    #[test]
    fn bad_inputs_error() {
        let mut p = problem(3, 9, 3.0);
        p.alphas[1] = f64::NAN;
        assert!(p.solve().is_err());
        let mut p2 = problem(3, 9, 3.0);
        p2.bit_choices.clear();
        assert!(p2.solve().is_err());
        let mut p3 = problem(3, 9, 3.0);
        p3.alphas.pop();
        assert!(p3.solve().is_err());
    }

    #[test]
    fn higher_budget_never_increases_cost() {
        let base = problem(10, 11, 2.2);
        let mut prev_cost = f64::INFINITY;
        for avg in [2.2, 3.0, 4.0, 6.0] {
            let mut p = base.clone();
            p.budget = AllocProblem::budget_for_avg_bits(&p.m, avg);
            let sol = p.solve().unwrap();
            assert!(sol.cost <= prev_cost + 1e-12, "avg={avg}");
            prev_cost = sol.cost;
        }
    }

    #[test]
    fn alpha_formula() {
        let a = alpha_from_calib(256, 2.0, 3.0, 4.0);
        assert!((a - 24.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn property_dp_beats_random_assignments() {
        // DP must be <= any random feasible assignment's cost (50 trials).
        let p = problem(12, 17, 3.0);
        let sol = p.solve().unwrap();
        let mut rng = Rng::new(99);
        let mut tried = 0;
        while tried < 50 {
            let bits: Vec<u8> = (0..12)
                .map(|_| p.bit_choices[rng.below(p.bit_choices.len())])
                .collect();
            let used: u64 = bits.iter().zip(&p.m).map(|(&b, &m)| b as u64 * m as u64).sum();
            if used > p.budget {
                continue;
            }
            tried += 1;
            let cost: f64 = bits
                .iter()
                .zip(&p.alphas)
                .map(|(&b, &a)| a * 2f64.powi(-(b as i32)))
                .sum();
            assert!(sol.cost <= cost + 1e-9);
        }
    }
}
