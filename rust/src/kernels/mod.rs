//! Fused CPU inference kernels: the hot-path compute layer the serving
//! stack routes through (EXPERIMENTS.md §Perf).
//!
//! * [`qgemm`] — packed-code GEMM: estimates `X @ V` directly from RaBitQ
//!   bit-packed codes (paper Alg. 3), cache-blocked and thread-parallel.
//!   Codes are decoded once per (depth-block × column-block) tile into a
//!   per-task scratch buffer and reused across every activation row, so
//!   the bit-unpacking cost is amortized `n`-fold and the working set
//!   (tile + accumulator) stays cache-resident.
//! * [`gemm`] — dense f32 GEMM with a 4-row register-tiled microkernel and
//!   row-block parallelism; backs `Matrix::matmul` (calibration, baselines,
//!   and the native model's full-precision layers).
//! * [`decode_codes_into`] — the shared bit decoder: width-specialized,
//!   branch-free bulk bodies for 1/2/4/8-bit codes (fixed lanes per byte,
//!   shaped for compiler autovectorization), a streaming bit-window
//!   decoder for 3/5/6/7; prologue/epilogue handle mid-byte tails and are
//!   pinned byte-exact by the golden decode vectors.
//!
//! * [`attend_cached`] — single-query multi-head attention over a
//!   contiguous K/V row window. Both the full causal forward and the
//!   KV-cached `decode_step` route through this one kernel, which is what
//!   makes incremental decoding **bit-identical** to full recompute.
//! * [`attend_cached_q`] — the same attention shape computed **directly
//!   over RaBitQ-packed K/V codes** (the [`crate::kvq`] storage): scores
//!   via the Algorithm-3 inner-product estimator per cached row, value
//!   mixing as a weighted sum of decoded codes, with the per-head RHT
//!   rotation folded into the query and inverted on the output.
//!
//! Threading: `threads == 0` means [`threadpool::default_threads`] (the
//! `RAANA_THREADS` override applies). Every parallel kernel runs on the
//! process-wide persistent pool ([`threadpool::global`]) — work is handed
//! out as fixed, caller-defined chunks, so no spawn/join barrier is paid
//! per call. All kernels are bit-deterministic in the thread count *and*
//! in the pool size — every output element is produced by exactly one task
//! with a fixed reduction order. A second, stricter contract backs the KV
//! cache: every kernel computes each output **row** with a reduction order
//! that does not depend on how many rows are in the batch, so a 1-row
//! decode step reproduces the corresponding row of an n-row prefill
//! bit-for-bit.
#![deny(missing_docs)]

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::hadamard::PracticalRht;
use crate::rabitq::{grid_center, PackedCodes, QuantizedMatrix};
use crate::tensor::Matrix;
use crate::threadpool;

/// Process-wide count of [`qgemm`] invocations — the packed-code GEMM is
/// *the* serving hot-path kernel, so this counter (exposed as
/// `raana_qgemm_calls_total` in the metrics registry) is the live
/// equivalent of the offline BENCH_kernels.json call counts.
static QGEMM_CALLS: AtomicUsize = AtomicUsize::new(0);

/// Read the packed-code GEMM invocation counter.
pub fn qgemm_calls() -> usize {
    QGEMM_CALLS.load(Ordering::Relaxed)
}

/// Output-column block width of [`qgemm`] (accumulator panel width).
pub const COL_BLOCK: usize = 32;

/// Depth (inner-dimension) block of [`qgemm`]: the decoded tile holds
/// `DEPTH_BLOCK * COL_BLOCK` f32 values (32 KiB) — sized for L2 residency.
pub const DEPTH_BLOCK: usize = 256;

#[inline]
fn effective_threads(threads: usize) -> usize {
    if threads == 0 {
        threadpool::default_threads()
    } else {
        threads
    }
}

// ------------------------------------------------------------ bit decoding

/// Decode `out.len()` codes starting at element index `start` into f32.
///
/// Layout contract: codes are packed LSB-first at `bits` bits per element
/// (see [`PackedCodes::pack`]). Equivalent to `out[i] = codes.get(start+i)
/// as f32`, but byte-at-a-time instead of per-element bit arithmetic.
///
/// # Examples
///
/// ```
/// use raana::kernels::decode_codes_into;
/// use raana::rabitq::PackedCodes;
///
/// let packed = PackedCodes::pack(&[3, 0, 7, 5, 1], 3);
/// let mut out = vec![0.0f32; 3];
/// decode_codes_into(&packed, 1, &mut out);
/// assert_eq!(out, vec![0.0, 7.0, 5.0]);
/// ```
pub fn decode_codes_into(codes: &PackedCodes, start: usize, out: &mut [f32]) {
    debug_assert!(start + out.len() <= codes.len, "decode range out of bounds");
    decode_bits_into(&codes.data, codes.bits, start, out);
}

/// [`decode_codes_into`] over a raw packed-bit buffer (no [`PackedCodes`]
/// wrapper) — the entry point the quantized KV cache uses, whose per-layer
/// code buffers are plain byte vectors shared by many rows.
pub fn decode_bits_into(data: &[u8], bits: u8, start: usize, out: &mut [f32]) {
    let len = out.len();
    if len == 0 {
        return;
    }
    let bits = bits as usize;
    let mask: u32 = (1u32 << bits) - 1;
    let mut bitpos = start * bits;

    if bits == 1 || bits == 2 || bits == 4 || bits == 8 {
        let mut i = 0;
        // prologue to a byte boundary (reads never straddle bytes here
        // because off is a multiple of bits when bits divides 8)
        while bitpos % 8 != 0 && i < len {
            let w = data[bitpos >> 3] as u32;
            out[i] = ((w >> (bitpos & 7)) & mask) as f32;
            i += 1;
            bitpos += bits;
        }
        // bulk body: one width-specialized, branch-free pass over whole
        // bytes. The `match` runs once per call (not once per byte) and
        // each helper's inner loop has a fixed trip shape with no
        // per-element branches — the form LLVM autovectorizes (u8 load →
        // shift/mask lanes → f32 convert). Byte-exact vs the per-element
        // reference; the golden decode vectors pin every width's tails.
        let per_byte = 8 / bits;
        let byte0 = bitpos >> 3;
        let whole = (len - i) / per_byte;
        {
            let src = &data[byte0..byte0 + whole];
            let dst = &mut out[i..i + whole * per_byte];
            match bits {
                8 => decode_bytes_w8(src, dst),
                4 => decode_bytes_w4(src, dst),
                2 => decode_bytes_w2(src, dst),
                _ => decode_bytes_w1(src, dst),
            }
        }
        i += whole * per_byte;
        bitpos = (byte0 + whole) * 8;
        // epilogue: mid-byte tail (fewer than per_byte codes left)
        while i < len {
            let w = data[bitpos >> 3] as u32;
            out[i] = ((w >> (bitpos & 7)) & mask) as f32;
            i += 1;
            bitpos += bits;
        }
        return;
    }

    // streaming bit-window decoder for 3/5/6/7-bit codes
    decode_bits_streaming(data, bits, mask, bitpos, out);
}

/// 8-bit bulk body: one code per byte, straight widening convert.
#[inline]
fn decode_bytes_w8(src: &[u8], dst: &mut [f32]) {
    for (o, &b) in dst.iter_mut().zip(src) {
        *o = b as f32;
    }
}

/// 4-bit bulk body: two lanes per byte, fixed shift/mask per lane.
#[inline]
fn decode_bytes_w4(src: &[u8], dst: &mut [f32]) {
    for (o, &b) in dst.chunks_exact_mut(2).zip(src) {
        o[0] = (b & 15) as f32;
        o[1] = (b >> 4) as f32;
    }
}

/// 2-bit bulk body: four lanes per byte.
#[inline]
fn decode_bytes_w2(src: &[u8], dst: &mut [f32]) {
    for (o, &b) in dst.chunks_exact_mut(4).zip(src) {
        o[0] = (b & 3) as f32;
        o[1] = ((b >> 2) & 3) as f32;
        o[2] = ((b >> 4) & 3) as f32;
        o[3] = (b >> 6) as f32;
    }
}

/// 1-bit bulk body: eight lanes per byte, fully unrolled.
#[inline]
fn decode_bytes_w1(src: &[u8], dst: &mut [f32]) {
    for (o, &b) in dst.chunks_exact_mut(8).zip(src) {
        o[0] = (b & 1) as f32;
        o[1] = ((b >> 1) & 1) as f32;
        o[2] = ((b >> 2) & 1) as f32;
        o[3] = ((b >> 3) & 1) as f32;
        o[4] = ((b >> 4) & 1) as f32;
        o[5] = ((b >> 5) & 1) as f32;
        o[6] = ((b >> 6) & 1) as f32;
        o[7] = (b >> 7) as f32;
    }
}

/// Streaming bit-window decoder for the widths that straddle bytes
/// (3/5/6/7): maintain a shift register of pending bits.
#[inline]
fn decode_bits_streaming(data: &[u8], bits: usize, mask: u32, bitpos: usize, out: &mut [f32]) {
    let mut byte = bitpos >> 3;
    let off = bitpos & 7;
    let mut acc: u32 = (data[byte] as u32) >> off;
    let mut navail = 8 - off;
    byte += 1;
    for o in out.iter_mut() {
        while navail < bits {
            acc |= (data[byte] as u32) << navail;
            byte += 1;
            navail += 8;
        }
        *o = (acc & mask) as f32;
        acc >>= bits;
        navail -= bits;
    }
}

// ------------------------------------------------------------------- qgemm

/// Packed-code GEMM (paper Alg. 3): estimate `X @ V` where `V` is held as
/// RaBitQ codes, without materializing `V` in float.
///
/// `X` is `(n × d)` rotated activations, `qm` holds a `(d × c)` quantized
/// matrix; the result is `(n × c)` with
/// `out[i][j] = r_j * (<x_i, codes_j> - c_b * sum(x_i))`.
///
/// Parallel over output-column blocks; each task decodes its code tile
/// once per depth block and reuses it across all `n` rows. Deterministic
/// in `threads` (0 = default).
pub fn qgemm(x: &Matrix, qm: &QuantizedMatrix, threads: usize) -> Matrix {
    assert_eq!(x.cols, qm.d, "qgemm shape mismatch");
    QGEMM_CALLS.fetch_add(1, Ordering::Relaxed);
    let (n, c) = (x.rows, qm.c);
    let mut out = Matrix::zeros(n, c);
    if n == 0 || c == 0 {
        return out;
    }
    let threads = effective_threads(threads);
    let cb = grid_center(qm.bits);
    let row_sums: Vec<f32> = (0..n).map(|i| x.row(i).iter().sum()).collect();

    let blocks: Vec<usize> = (0..c).step_by(COL_BLOCK).collect();
    let results = threadpool::parallel_map(&blocks, threads, |_, &j0| {
        qgemm_block(x, qm, cb, &row_sums, j0, (j0 + COL_BLOCK).min(c))
    });

    // stitch the per-block (n × jb) panels into the row-major output
    for (bi, block) in results.iter().enumerate() {
        let j0 = bi * COL_BLOCK;
        let jb = (j0 + COL_BLOCK).min(c) - j0;
        for i in 0..n {
            out.row_mut(i)[j0..j0 + jb].copy_from_slice(&block[i * jb..(i + 1) * jb]);
        }
    }
    out
}

/// One column block of [`qgemm`]: returns the finalized `(n × jb)` panel.
fn qgemm_block(
    x: &Matrix,
    qm: &QuantizedMatrix,
    cb: f32,
    row_sums: &[f32],
    j0: usize,
    j1: usize,
) -> Vec<f32> {
    let (n, d) = (x.rows, qm.d);
    let jb = j1 - j0;
    let mut acc = vec![0f32; n * jb];
    let mut tile = vec![0f32; DEPTH_BLOCK * jb];
    let mut colbuf = vec![0f32; DEPTH_BLOCK];

    let mut k0 = 0;
    while k0 < d {
        let klen = DEPTH_BLOCK.min(d - k0);
        // decode the (klen × jb) tile once; column j's codes live at
        // element range [j*d + k0, j*d + k0 + klen)
        for jj in 0..jb {
            decode_codes_into(&qm.codes, (j0 + jj) * d + k0, &mut colbuf[..klen]);
            for (kk, &v) in colbuf[..klen].iter().enumerate() {
                tile[kk * jb + jj] = v;
            }
        }
        // accumulate: every activation row reuses the decoded tile
        for i in 0..n {
            let xrow = &x.row(i)[k0..k0 + klen];
            let accrow = &mut acc[i * jb..(i + 1) * jb];
            for (kk, &a) in xrow.iter().enumerate() {
                let trow = &tile[kk * jb..kk * jb + jb];
                for (o, &t) in accrow.iter_mut().zip(trow) {
                    *o += a * t;
                }
            }
        }
        k0 += klen;
    }

    // finalize: out = r_j * (acc - c_b * row_sum)
    for i in 0..n {
        let rs = cb * row_sums[i];
        let accrow = &mut acc[i * jb..(i + 1) * jb];
        for (jj, o) in accrow.iter_mut().enumerate() {
            *o = qm.r[j0 + jj] * (*o - rs);
        }
    }
    acc
}

// -------------------------------------------------------- cached attention

/// Single-query multi-head attention over a contiguous K/V row window —
/// the gather path the KV cache serves (`ctx` cached rows, one query).
///
/// `q` is one (d,) query row with `d = n_heads * head_dim`; `k_rows` /
/// `v_rows` hold `ctx` rows of length `d` back to back (either the
/// in-forward K/V matrices of a full causal pass or a
/// [`crate::runtime::KvCache`] slot's filled prefix). Per head: scaled
/// dot-product scores against all `ctx` keys, a max-shifted softmax, and
/// the weighted value sum **accumulated into** `out[head window]` (callers
/// pass a zeroed `out`). `scores` is caller-owned scratch of length
/// `>= ctx` so batch loops allocate nothing per query.
///
/// This is the single implementation of attention arithmetic in the crate:
/// the full forward calls it once per (batch row, query position) and
/// `decode_step` once per active slot, so cached decoding is bit-identical
/// to full recompute by construction (same reduction order per row).
pub fn attend_cached(
    q: &[f32],
    k_rows: &[f32],
    v_rows: &[f32],
    ctx: usize,
    n_heads: usize,
    head_dim: usize,
    scores: &mut [f32],
    out: &mut [f32],
) {
    let d = n_heads * head_dim;
    debug_assert!(ctx >= 1, "attention needs at least one cached row");
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    debug_assert!(k_rows.len() >= ctx * d && v_rows.len() >= ctx * d);
    debug_assert!(scores.len() >= ctx);
    let scale = 1.0 / (head_dim as f32).sqrt();
    for head in 0..n_heads {
        let hoff = head * head_dim;
        let qrow = &q[hoff..hoff + head_dim];
        let mut maxs = f32::NEG_INFINITY;
        for (ki, sc) in scores[..ctx].iter_mut().enumerate() {
            let krow = &k_rows[ki * d + hoff..ki * d + hoff + head_dim];
            let mut dp = 0f32;
            for t in 0..head_dim {
                dp += qrow[t] * krow[t];
            }
            *sc = dp * scale;
            maxs = maxs.max(*sc);
        }
        let mut denom = 0f32;
        for sc in scores[..ctx].iter_mut() {
            *sc = (*sc - maxs).exp();
            denom += *sc;
        }
        let inv = 1.0 / denom;
        let orow = &mut out[hoff..hoff + head_dim];
        for (ki, &sc) in scores[..ctx].iter().enumerate() {
            let w = sc * inv;
            let vrow = &v_rows[ki * d + hoff..ki * d + hoff + head_dim];
            for (ov, &vv) in orow.iter_mut().zip(vrow) {
                *ov += w * vv;
            }
        }
    }
}

// ---------------------------------------------- quantized cached attention

/// A read-only view of `ctx` RaBitQ-coded rows inside a shared packed-bit
/// buffer — how [`crate::kvq::QuantizedKvStore`] hands cached K or V rows
/// to [`attend_cached_q`] without materializing any f32 row storage.
///
/// Row `i` occupies elements `[start + i*d, start + (i+1)*d)` of the
/// bit-packed `data` (at `bits` bits per element, LSB-first — the
/// [`PackedCodes`] layout); `r[i * n_heads + h]` is the least-squares
/// rescale of row `i`'s head-`h` segment, so each head segment of each row
/// reconstructs as `r * (codes - grid_center(bits))`.
#[derive(Clone, Copy, Debug)]
pub struct QuantView<'a> {
    /// Packed code payload (may cover many rows beyond this window).
    pub data: &'a [u8],
    /// Bits per code (1..=8).
    pub bits: u8,
    /// Element index of the window's row 0 within `data`.
    pub start: usize,
    /// Per-(row, head) rescales, row-major: `r[row * n_heads + head]`.
    pub r: &'a [f32],
}

/// Caller-owned scratch for [`attend_cached_q`]: one allocation per batch
/// loop, reused across every query (the kernel itself allocates nothing).
#[derive(Clone, Debug)]
pub struct AttendQScratch {
    /// Rotated query row (d).
    q_rot: Vec<f32>,
    /// One decoded code row (d).
    row: Vec<f32>,
    /// Rotated-space output accumulator (d).
    acc: Vec<f32>,
    /// Head-major score/weight table (n_heads * ctx_max).
    scores: Vec<f32>,
    /// Per-head query sums, then per-head weight·rescale sums (n_heads).
    hsum: Vec<f32>,
}

impl AttendQScratch {
    /// Scratch sized for `d = n_heads * head_dim` queries over windows of
    /// up to `ctx_max` cached rows.
    pub fn new(d: usize, n_heads: usize, ctx_max: usize) -> AttendQScratch {
        AttendQScratch {
            q_rot: vec![0.0; d],
            row: vec![0.0; d],
            acc: vec![0.0; d],
            scores: vec![0.0; n_heads * ctx_max],
            hsum: vec![0.0; n_heads],
        }
    }
}

/// [`attend_cached`] computed **directly over RaBitQ codes**: single-query
/// multi-head attention where the `ctx` cached K and V rows live as
/// bit-packed codes ([`QuantView`]) whose head segments were RHT-rotated
/// (`rot`, dimension `head_dim`) before quantization.
///
/// Per head `h` with query segment `q_h`:
///
/// * **scores** — the rotation is orthonormal, so `<q_h, k_h> =
///   <rot(q_h), rot(k_h)>`; the kernel rotates the query once and applies
///   the Algorithm-3 estimator per cached row: `score = r_k * (<q̂_h,
///   codes> - c_b * Σ q̂_h) / sqrt(head_dim)` — no K row is ever
///   reconstructed.
/// * **mixing** — softmax weights combine the V rows *in rotated space*
///   (`Σ_i w_i r_v,i (codes_i - c_b)`, decoded once per row), and the
///   inverse rotation maps the mixed vector back before it is
///   **accumulated into** `out[head window]` (callers pass a zeroed `out`,
///   matching the [`attend_cached`] contract).
///
/// Each output row reduces in a fixed, batch-size-independent order, so a
/// 1-row decode step reproduces the corresponding row of an n-row prefill
/// bit-for-bit — the same contract the dense kernel upholds. Accuracy is
/// *bounded drift* against [`attend_cached`] over the f32 rows: the error
/// decays ~2^-bits per the RaBitQ bound (property-tested, and pinned by
/// the `kvq_attend` golden vectors).
#[allow(clippy::too_many_arguments)]
pub fn attend_cached_q(
    q: &[f32],
    k: QuantView<'_>,
    v: QuantView<'_>,
    ctx: usize,
    n_heads: usize,
    head_dim: usize,
    rot: &PracticalRht,
    scratch: &mut AttendQScratch,
    out: &mut [f32],
) {
    let d = n_heads * head_dim;
    debug_assert!(ctx >= 1, "attention needs at least one cached row");
    debug_assert_eq!(q.len(), d);
    debug_assert_eq!(out.len(), d);
    debug_assert_eq!(rot.d, head_dim, "rotation dimension must be head_dim");
    debug_assert!(k.r.len() >= ctx * n_heads && v.r.len() >= ctx * n_heads);
    debug_assert!(scratch.q_rot.len() == d && scratch.scores.len() >= n_heads * ctx);
    let scale = 1.0 / (head_dim as f32).sqrt();

    // rotate the query once; cache per-head sums for the estimator
    scratch.q_rot.copy_from_slice(q);
    for h in 0..n_heads {
        let seg = &mut scratch.q_rot[h * head_dim..(h + 1) * head_dim];
        rot.apply(seg);
        scratch.hsum[h] = seg.iter().sum();
    }

    // scores: decode each K row once, estimate every head's logit from it
    let cbk = grid_center(k.bits);
    for ki in 0..ctx {
        decode_bits_into(k.data, k.bits, k.start + ki * d, &mut scratch.row);
        for h in 0..n_heads {
            let hoff = h * head_dim;
            let qseg = &scratch.q_rot[hoff..hoff + head_dim];
            let kseg = &scratch.row[hoff..hoff + head_dim];
            let mut dp = 0f32;
            for t in 0..head_dim {
                dp += qseg[t] * kseg[t];
            }
            let est = k.r[ki * n_heads + h] * (dp - cbk * scratch.hsum[h]);
            scratch.scores[h * ctx + ki] = est * scale;
        }
    }

    // per-head max-shifted softmax, in place (scores become weights)
    for h in 0..n_heads {
        let sc = &mut scratch.scores[h * ctx..(h + 1) * ctx];
        let maxs = sc.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut denom = 0f32;
        for s in sc.iter_mut() {
            *s = (*s - maxs).exp();
            denom += *s;
        }
        let inv = 1.0 / denom;
        for s in sc.iter_mut() {
            *s *= inv;
        }
    }

    // value mixing in rotated space: acc_h = Σ_i w_i r_i codes_i - c_b Σ w_i r_i
    let cbv = grid_center(v.bits);
    scratch.acc.iter_mut().for_each(|x| *x = 0.0);
    scratch.hsum.iter_mut().for_each(|x| *x = 0.0);
    for ki in 0..ctx {
        decode_bits_into(v.data, v.bits, v.start + ki * d, &mut scratch.row);
        for h in 0..n_heads {
            let hoff = h * head_dim;
            let wr = scratch.scores[h * ctx + ki] * v.r[ki * n_heads + h];
            scratch.hsum[h] += wr;
            let vseg = &scratch.row[hoff..hoff + head_dim];
            let aseg = &mut scratch.acc[hoff..hoff + head_dim];
            for (a, &c) in aseg.iter_mut().zip(vseg) {
                *a += wr * c;
            }
        }
    }
    // subtract the grid-center term, invert the rotation, accumulate out
    for h in 0..n_heads {
        let hoff = h * head_dim;
        let shift = cbv * scratch.hsum[h];
        let aseg = &mut scratch.acc[hoff..hoff + head_dim];
        for a in aseg.iter_mut() {
            *a -= shift;
        }
        rot.apply_inverse(aseg);
        for (o, &a) in out[hoff..hoff + head_dim].iter_mut().zip(aseg.iter()) {
            *o += a;
        }
    }
}

// ----------------------------------------------------------- index scans

/// Estimated inner-product scan over RaBitQ-coded rows — phase 1 of the
/// vector index's two-phase query ([`crate::index`]).
///
/// `q_rot` is the query **already rotated** into the rows' coded basis
/// (the rotation is orthonormal, so `<q, row> = <q_rot, rot(row))>`);
/// `data` holds `n` rows of `d` codes each, packed LSB-first at `bits`
/// bits per element starting at element index `start` (the
/// [`crate::rabitq::PackedCodes`] layout); `r[i]` is row `i`'s
/// least-squares rescale. Writes one Algorithm-3 estimate per row:
///
/// ```text
/// out[i] = r[i] * (<q_rot, codes_i> - c_b * Σ q_rot)
/// ```
///
/// No row is ever reconstructed in f32 — codes are decoded into one
/// per-task scratch row and consumed by the dot product directly, which
/// is what keeps the scan's memory traffic at `bits/32` of the dense
/// baseline. Parallel over row blocks; every output element is produced
/// by exactly one task with a fixed reduction order, so the scan is
/// bit-deterministic in `threads` (0 = default).
pub fn scan_scores_q(
    q_rot: &[f32],
    data: &[u8],
    bits: u8,
    start: usize,
    n: usize,
    r: &[f32],
    threads: usize,
    out: &mut [f32],
) {
    let d = q_rot.len();
    debug_assert!(r.len() >= n && out.len() >= n);
    if n == 0 {
        return;
    }
    let cb = grid_center(bits);
    let qsum: f32 = q_rot.iter().sum();
    let threads = effective_threads(threads);
    // block size: amortize scratch allocation, stay cache-resident
    const ROW_BLOCK: usize = 64;
    if threads <= 1 || n <= ROW_BLOCK {
        let mut row = vec![0f32; d];
        scan_rows_q(q_rot, data, bits, start, 0, n, r, cb, qsum, &mut row, out);
        return;
    }
    threadpool::parallel_chunks_mut(&mut out[..n], ROW_BLOCK, threads, |idx, chunk| {
        let mut row = vec![0f32; d];
        let i0 = idx * ROW_BLOCK;
        scan_rows_q(q_rot, data, bits, start, i0, chunk.len(), r, cb, qsum, &mut row, chunk);
    });
}

/// Serial inner loop of [`scan_scores_q`] over rows `[i0, i0 + len)`,
/// writing into `out[..len]`.
#[allow(clippy::too_many_arguments)]
fn scan_rows_q(
    q_rot: &[f32],
    data: &[u8],
    bits: u8,
    start: usize,
    i0: usize,
    len: usize,
    r: &[f32],
    cb: f32,
    qsum: f32,
    row: &mut [f32],
    out: &mut [f32],
) {
    let d = q_rot.len();
    for (j, o) in out.iter_mut().take(len).enumerate() {
        let i = i0 + j;
        decode_bits_into(data, bits, start + i * d, row);
        let mut dp = 0f32;
        for (x, c) in q_rot.iter().zip(row.iter()) {
            dp += x * c;
        }
        *o = r[i] * (dp - cb * qsum);
    }
}

/// Exact f32 inner-product scan — the brute-force baseline phase 1 is
/// measured against (`index_scan_f32` in `benches/kernels.rs`) and the
/// kernel the rerank phase applies to its candidate set. `rows` holds `n`
/// contiguous rows of length `q.len()`. Parallel over row blocks,
/// bit-deterministic in `threads` (0 = default).
pub fn scan_scores_f32(q: &[f32], rows: &[f32], n: usize, threads: usize, out: &mut [f32]) {
    let d = q.len();
    debug_assert!(rows.len() >= n * d && out.len() >= n);
    if n == 0 {
        return;
    }
    let threads = effective_threads(threads);
    const ROW_BLOCK: usize = 64;
    let scan = |i0: usize, chunk: &mut [f32]| {
        for (j, o) in chunk.iter_mut().enumerate() {
            let row = &rows[(i0 + j) * d..(i0 + j + 1) * d];
            let mut dp = 0f32;
            for (x, v) in q.iter().zip(row) {
                dp += x * v;
            }
            *o = dp;
        }
    };
    if threads <= 1 || n <= ROW_BLOCK {
        scan(0, &mut out[..n]);
        return;
    }
    threadpool::parallel_chunks_mut(&mut out[..n], ROW_BLOCK, threads, |idx, chunk| {
        scan(idx * ROW_BLOCK, chunk);
    });
}

// -------------------------------------------------------------- dense gemm

/// Dense f32 GEMM: `out += A (m×k) @ B (k×n)`, row-major slices.
///
/// 4-row register-tiled microkernel, parallel over row blocks. Callers
/// pass a zeroed `out` for a plain product. Deterministic in `threads`
/// (0 = default); small problems run serially to skip thread-spawn cost.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32], threads: usize) {
    assert_eq!(a.len(), m * k, "gemm: A size");
    assert_eq!(b.len(), k * n, "gemm: B size");
    assert_eq!(out.len(), m * n, "gemm: out size");
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let threads = effective_threads(threads);
    let flops = m as u128 * n as u128 * k as u128;
    if threads <= 1 || flops < (1u128 << 16) || m < 8 {
        gemm_rows(a, k, n, b, out);
        return;
    }
    // rows per task, rounded to the microkernel height
    let per = {
        let p = m.div_ceil(threads * 2);
        ((p + 3) / 4) * 4
    };
    threadpool::parallel_chunks_mut(out, per * n, threads, |idx, chunk| {
        let row0 = idx * per;
        let rows = chunk.len() / n;
        gemm_rows(&a[row0 * k..(row0 + rows) * k], k, n, b, chunk);
    });
}

/// Serial kernel over a row panel: `out (r×n) += A (r×k) @ B (k×n)`.
fn gemm_rows(a: &[f32], k: usize, n: usize, b: &[f32], out: &mut [f32]) {
    let r = out.len() / n;
    debug_assert_eq!(a.len(), r * k);
    let mut rows: Vec<&mut [f32]> = out.chunks_mut(n).collect();
    let mut i = 0;
    while i + 4 <= r {
        let quad = &mut rows[i..i + 4];
        micro4(&a[i * k..(i + 4) * k], k, n, b, quad);
        i += 4;
    }
    while i < r {
        let arow = &a[i * k..(i + 1) * k];
        let orow: &mut [f32] = &mut rows[i];
        // No zero-skip here: a row must reduce in the exact same order
        // whether it lands in this remainder loop or in `micro4`, so that
        // per-row results are independent of the batch's row grouping (the
        // KV-decode bit-exactness contract).
        for (kk, &x) in arow.iter().enumerate() {
            let bv = &b[kk * n..kk * n + n];
            for (o, &bj) in orow.iter_mut().zip(bv) {
                *o += x * bj;
            }
        }
        i += 1;
    }
}

/// 4-row microkernel: each B row is loaded once and reused by 4 A rows
/// held in registers (4× memory-traffic reduction over the scalar loop).
fn micro4(a: &[f32], k: usize, n: usize, b: &[f32], rows: &mut [&mut [f32]]) {
    let (a0, rest) = a.split_at(k);
    let (a1, rest) = rest.split_at(k);
    let (a2, a3) = rest.split_at(k);
    let (r0, rest) = rows.split_first_mut().expect("4 rows");
    let (r1, rest) = rest.split_first_mut().expect("4 rows");
    let (r2, rest) = rest.split_first_mut().expect("4 rows");
    let (r3, _) = rest.split_first_mut().expect("4 rows");
    let r0 = &mut r0[..n];
    let r1 = &mut r1[..n];
    let r2 = &mut r2[..n];
    let r3 = &mut r3[..n];
    for kk in 0..k {
        let bv = &b[kk * n..kk * n + n];
        let (x0, x1, x2, x3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
        for j in 0..n {
            let bj = bv[j];
            r0[j] += x0 * bj;
            r1[j] += x1 * bj;
            r2[j] += x2 * bj;
            r3[j] += x3 * bj;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rabitq::ScaleMode;
    use crate::rng::Rng;

    fn random_matrix(r: usize, c: usize, seed: u64) -> Matrix {
        Matrix::from_vec(r, c, Rng::new(seed).gaussian_vec(r * c))
    }

    #[test]
    fn decode_matches_packed_get_all_bits() {
        let mut rng = Rng::new(11);
        for bits in 1..=8u8 {
            let maxv = (1u32 << bits) as usize;
            let values: Vec<u8> = (0..1237).map(|_| rng.below(maxv) as u8).collect();
            let packed = PackedCodes::pack(&values, bits);
            // whole-range and random sub-range decodes
            for (start, len) in [(0usize, 1237usize), (1, 700), (513, 724), (1236, 1), (7, 0)] {
                let mut out = vec![0f32; len];
                decode_codes_into(&packed, start, &mut out);
                for (i, &o) in out.iter().enumerate() {
                    assert_eq!(o, values[start + i] as f32, "bits={bits} start={start} i={i}");
                }
            }
        }
    }

    #[test]
    fn qgemm_matches_dense_reference_all_bits() {
        // odd / non-pow2 shapes on purpose
        for (n, d, c) in [(5usize, 97usize, 33usize), (3, 64, 1), (8, 300, 40)] {
            for bits in 1..=8u8 {
                let v = random_matrix(d, c, 100 + bits as u64);
                let x = random_matrix(n, d, 200 + bits as u64);
                let qm = QuantizedMatrix::quantize(&v, bits, ScaleMode::MaxAbs, 2);
                let got = qgemm(&x, &qm, 3);
                let want = x.matmul(&qm.dequantize());
                let rel = got.rel_err(&want);
                assert!(rel < 1e-4, "bits={bits} n={n} d={d} c={c} rel={rel}");
            }
        }
    }

    #[test]
    fn qgemm_empty_batch_and_single_column() {
        let v = random_matrix(48, 1, 1);
        let qm = QuantizedMatrix::quantize(&v, 4, ScaleMode::MaxAbs, 1);
        let x0 = Matrix::zeros(0, 48);
        let y0 = qgemm(&x0, &qm, 4);
        assert_eq!((y0.rows, y0.cols), (0, 1));
        let x1 = random_matrix(2, 48, 2);
        let y1 = qgemm(&x1, &qm, 4);
        let want = x1.matmul(&qm.dequantize());
        assert!(y1.rel_err(&want) < 1e-4);
    }

    #[test]
    fn qgemm_deterministic_across_thread_counts() {
        let v = random_matrix(130, 70, 3);
        let x = random_matrix(9, 130, 4);
        let qm = QuantizedMatrix::quantize(&v, 3, ScaleMode::MaxAbs, 1);
        let a = qgemm(&x, &qm, 1);
        let b = qgemm(&x, &qm, 8);
        assert_eq!(a.data, b.data, "qgemm must be bit-deterministic in threads");
    }

    #[test]
    fn qgemm_spans_column_blocks() {
        // c > COL_BLOCK exercises the block stitch
        let c = COL_BLOCK * 2 + 5;
        let v = random_matrix(64, c, 5);
        let x = random_matrix(4, 64, 6);
        let qm = QuantizedMatrix::quantize(&v, 5, ScaleMode::MaxAbs, 2);
        let got = qgemm(&x, &qm, 4);
        let want = x.matmul(&qm.dequantize());
        assert!(got.rel_err(&want) < 1e-4);
    }

    #[test]
    fn qgemm_spans_depth_blocks() {
        // d > DEPTH_BLOCK exercises tile accumulation across k blocks
        let d = DEPTH_BLOCK + 37;
        let v = random_matrix(d, 10, 7);
        let x = random_matrix(3, d, 8);
        let qm = QuantizedMatrix::quantize(&v, 6, ScaleMode::MaxAbs, 2);
        let got = qgemm(&x, &qm, 2);
        let want = x.matmul(&qm.dequantize());
        assert!(got.rel_err(&want) < 1e-4);
    }

    #[test]
    fn attend_cached_matches_naive_softmax_attention() {
        let (hn, hd, ctx) = (2usize, 4usize, 5usize);
        let d = hn * hd;
        let q = Rng::new(50).gaussian_vec(d);
        let k = Rng::new(51).gaussian_vec(ctx * d);
        let v = Rng::new(52).gaussian_vec(ctx * d);
        let mut scores = vec![0f32; ctx];
        let mut out = vec![0f32; d];
        attend_cached(&q, &k, &v, ctx, hn, hd, &mut scores, &mut out);

        // f64 reference, per head
        for head in 0..hn {
            let hoff = head * hd;
            let mut sc: Vec<f64> = (0..ctx)
                .map(|ki| {
                    (0..hd)
                        .map(|t| q[hoff + t] as f64 * k[ki * d + hoff + t] as f64)
                        .sum::<f64>()
                        / (hd as f64).sqrt()
                })
                .collect();
            let maxs = sc.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let denom: f64 = sc.iter().map(|s| (s - maxs).exp()).sum();
            for s in sc.iter_mut() {
                *s = (*s - maxs).exp() / denom;
            }
            for t in 0..hd {
                let want: f64 = (0..ctx)
                    .map(|ki| sc[ki] * v[ki * d + hoff + t] as f64)
                    .sum();
                assert!(
                    (out[hoff + t] as f64 - want).abs() < 1e-4,
                    "head {head} t {t}: {} vs {want}",
                    out[hoff + t]
                );
            }
        }
    }

    #[test]
    fn attend_cached_single_row_is_value_passthrough() {
        // ctx == 1: softmax over one key is 1, so out == v row exactly
        let (hn, hd) = (2usize, 8usize);
        let d = hn * hd;
        let q = Rng::new(53).gaussian_vec(d);
        let k = Rng::new(54).gaussian_vec(d);
        let v = Rng::new(55).gaussian_vec(d);
        let mut scores = vec![0f32; 1];
        let mut out = vec![0f32; d];
        attend_cached(&q, &k, &v, 1, hn, hd, &mut scores, &mut out);
        assert_eq!(out, v);
    }

    #[test]
    fn gemm_rows_bit_identical_across_batch_grouping() {
        // the KV-decode contract: row i of an m-row product must equal the
        // same row computed alone (micro4 vs remainder path, any threads)
        let (m, k, n) = (11usize, 40usize, 24usize);
        let a = random_matrix(m, k, 60);
        let b = random_matrix(k, n, 61);
        let mut full = vec![0f32; m * n];
        gemm(m, k, n, &a.data, &b.data, &mut full, 4);
        for i in 0..m {
            let mut single = vec![0f32; n];
            gemm(1, k, n, a.row(i), &b.data, &mut single, 1);
            assert_eq!(&full[i * n..(i + 1) * n], &single[..], "row {i}");
        }
    }

    #[test]
    fn qgemm_bit_identical_across_batch_grouping() {
        let (d, c) = (96usize, 40usize);
        let v = random_matrix(d, c, 62);
        let x = random_matrix(6, d, 63);
        let qm = QuantizedMatrix::quantize(&v, 5, ScaleMode::MaxAbs, 2);
        let full = qgemm(&x, &qm, 4);
        for i in 0..x.rows {
            let xi = Matrix::from_vec(1, d, x.row(i).to_vec());
            let single = qgemm(&xi, &qm, 1);
            assert_eq!(full.row(i), single.row(0), "row {i}");
        }
    }

    /// Rotate + RaBitQ-quantize `ctx` rows per head (the kvq store recipe,
    /// inlined): returns (packed codes, per-(row,head) rescales,
    /// reconstructed f64 rows in the ORIGINAL basis).
    fn quantize_rows(
        rows: &[f32],
        ctx: usize,
        hn: usize,
        hd: usize,
        rot: &PracticalRht,
        bits: u8,
    ) -> (PackedCodes, Vec<f32>, Vec<f64>) {
        use crate::rabitq::{quantize_column, ScaleMode};
        let d = hn * hd;
        let mut all_codes = Vec::with_capacity(ctx * d);
        let mut r = Vec::with_capacity(ctx * hn);
        let mut rec = vec![0f64; ctx * d];
        for ki in 0..ctx {
            for h in 0..hn {
                let mut seg = rows[ki * d + h * hd..ki * d + (h + 1) * hd].to_vec();
                rot.apply(&mut seg);
                let (codes, rr) = quantize_column(&seg, bits, ScaleMode::MaxAbs);
                let cb = grid_center(bits);
                let mut seg_rec: Vec<f32> =
                    codes.iter().map(|&c| rr * (c as f32 - cb)).collect();
                rot.apply_inverse(&mut seg_rec);
                for (t, &x) in seg_rec.iter().enumerate() {
                    rec[ki * d + h * hd + t] = x as f64;
                }
                all_codes.extend_from_slice(&codes);
                r.push(rr);
            }
        }
        (PackedCodes::pack(&all_codes, bits), r, rec)
    }

    /// f64 reference attention over arbitrary (already reconstructed) rows.
    fn attend_ref_f64(
        q: &[f32],
        k: &[f64],
        v: &[f64],
        ctx: usize,
        hn: usize,
        hd: usize,
    ) -> Vec<f64> {
        let d = hn * hd;
        let mut out = vec![0f64; d];
        for h in 0..hn {
            let hoff = h * hd;
            let mut sc: Vec<f64> = (0..ctx)
                .map(|ki| {
                    (0..hd)
                        .map(|t| q[hoff + t] as f64 * k[ki * d + hoff + t])
                        .sum::<f64>()
                        / (hd as f64).sqrt()
                })
                .collect();
            let maxs = sc.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let denom: f64 = sc.iter().map(|s| (s - maxs).exp()).sum();
            for s in sc.iter_mut() {
                *s = (*s - maxs).exp() / denom;
            }
            for t in 0..hd {
                out[hoff + t] = (0..ctx).map(|ki| sc[ki] * v[ki * d + hoff + t]).sum();
            }
        }
        out
    }

    #[test]
    fn attend_cached_q_matches_reconstruction_reference() {
        // the kernel's fused estimator == attention over the reconstructed
        // rows (same math, different factorization) — for pow2 and non-pow2
        // head dims (the latter exercises both practical-RHT windows)
        for (hn, hd, ctx, bits) in
            [(2usize, 8usize, 6usize, 4u8), (4, 8, 12, 8), (2, 5, 7, 5), (3, 16, 9, 2)]
        {
            let d = hn * hd;
            let mut rng = Rng::new(700 + bits as u64);
            let rot = PracticalRht::sample(hd, &mut rng);
            let q = rng.gaussian_vec(d);
            let krows = rng.gaussian_vec(ctx * d);
            let vrows = rng.gaussian_vec(ctx * d);
            let (kp, kr, krec) = quantize_rows(&krows, ctx, hn, hd, &rot, bits);
            let (vp, vr, vrec) = quantize_rows(&vrows, ctx, hn, hd, &rot, bits);
            let mut scratch = AttendQScratch::new(d, hn, ctx);
            let mut out = vec![0f32; d];
            attend_cached_q(
                &q,
                QuantView { data: &kp.data, bits, start: 0, r: &kr },
                QuantView { data: &vp.data, bits, start: 0, r: &vr },
                ctx,
                hn,
                hd,
                &rot,
                &mut scratch,
                &mut out,
            );
            let want = attend_ref_f64(&q, &krec, &vrec, ctx, hn, hd);
            for (i, (&got, &exp)) in out.iter().zip(&want).enumerate() {
                assert!(
                    (got as f64 - exp).abs() < 2e-3,
                    "hn={hn} hd={hd} bits={bits} elem {i}: {got} vs {exp}"
                );
            }
        }
    }

    #[test]
    fn attend_cached_q_error_vs_dense_shrinks_with_bits() {
        // bounded drift vs the f32 kernel over the ORIGINAL rows, and a
        // monotone 2 -> 4 -> 8 bit quality ladder
        let (hn, hd, ctx) = (2usize, 16usize, 10usize);
        let d = hn * hd;
        let mut rng = Rng::new(900);
        let rot = PracticalRht::sample(hd, &mut rng);
        let q = rng.gaussian_vec(d);
        let krows = rng.gaussian_vec(ctx * d);
        let vrows = rng.gaussian_vec(ctx * d);
        let mut scores = vec![0f32; ctx];
        let mut exact = vec![0f32; d];
        attend_cached(&q, &krows, &vrows, ctx, hn, hd, &mut scores, &mut exact);
        let norm: f64 = exact.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();

        let mut prev = f64::INFINITY;
        for bits in [2u8, 4, 8] {
            let (kp, kr, _) = quantize_rows(&krows, ctx, hn, hd, &rot, bits);
            let (vp, vr, _) = quantize_rows(&vrows, ctx, hn, hd, &rot, bits);
            let mut scratch = AttendQScratch::new(d, hn, ctx);
            let mut out = vec![0f32; d];
            attend_cached_q(
                &q,
                QuantView { data: &kp.data, bits, start: 0, r: &kr },
                QuantView { data: &vp.data, bits, start: 0, r: &vr },
                ctx,
                hn,
                hd,
                &rot,
                &mut scratch,
                &mut out,
            );
            let err: f64 = out
                .iter()
                .zip(&exact)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
                / norm;
            assert!(err < prev, "bits={bits}: {err} !< {prev} (ladder must be monotone)");
            // generous constant (softmax amplifies low-bit logit error);
            // the point is the 2^-b scaling law
            assert!(err < 6.0 * 2f64.powi(-(bits as i32)), "bits={bits} err={err}");
            prev = err;
        }
        assert!(prev < 0.05, "8-bit attend drift too large: {prev}");
    }

    #[test]
    fn attend_cached_q_single_row_is_value_reconstruction() {
        // ctx == 1: softmax weight is exactly 1, so out == the V row's
        // quantized reconstruction (rotation round-tripped)
        let (hn, hd) = (2usize, 8usize);
        let d = hn * hd;
        let mut rng = Rng::new(901);
        let rot = PracticalRht::sample(hd, &mut rng);
        let q = rng.gaussian_vec(d);
        let krows = rng.gaussian_vec(d);
        let vrows = rng.gaussian_vec(d);
        let (kp, kr, _) = quantize_rows(&krows, 1, hn, hd, &rot, 8);
        let (vp, vr, vrec) = quantize_rows(&vrows, 1, hn, hd, &rot, 8);
        let mut scratch = AttendQScratch::new(d, hn, 1);
        let mut out = vec![0f32; d];
        attend_cached_q(
            &q,
            QuantView { data: &kp.data, bits: 8, start: 0, r: &kr },
            QuantView { data: &vp.data, bits: 8, start: 0, r: &vr },
            1,
            hn,
            hd,
            &rot,
            &mut scratch,
            &mut out,
        );
        for (i, (&got, &exp)) in out.iter().zip(&vrec).enumerate() {
            assert!((got as f64 - exp).abs() < 1e-4, "elem {i}: {got} vs {exp}");
        }
    }

    #[test]
    fn decode_bits_into_matches_wrapper() {
        let values: Vec<u8> = (0..131).map(|i| (i % 8) as u8).collect();
        let packed = PackedCodes::pack(&values, 3);
        let mut a = vec![0f32; 40];
        let mut b = vec![0f32; 40];
        decode_codes_into(&packed, 17, &mut a);
        decode_bits_into(&packed.data, 3, 17, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn scan_scores_q_matches_estimate_ip_per_row() {
        use crate::rabitq::{estimate_ip, quantize_column};
        // rows quantized individually; the fused scan must agree with the
        // per-row Algorithm-3 estimator for every width
        for (n, d, bits) in [(7usize, 24usize, 3u8), (16, 32, 4), (5, 20, 5), (64, 16, 8)] {
            let mut rng = Rng::new(4000 + bits as u64);
            let rows: Vec<Vec<f32>> = (0..n).map(|_| rng.gaussian_vec(d)).collect();
            let q = rng.gaussian_vec(d);
            let mut all_codes = Vec::with_capacity(n * d);
            let mut r = Vec::with_capacity(n);
            for row in &rows {
                let (codes, rr) = quantize_column(row, bits, ScaleMode::MaxAbs);
                all_codes.extend_from_slice(&codes);
                r.push(rr);
            }
            let packed = PackedCodes::pack(&all_codes, bits);
            let mut out = vec![0f32; n];
            scan_scores_q(&q, &packed.data, bits, 0, n, &r, 2, &mut out);
            for i in 0..n {
                let want = estimate_ip(&q, &all_codes[i * d..(i + 1) * d], r[i], bits);
                assert!(
                    (out[i] as f64 - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "n={n} d={d} bits={bits} row {i}: {} vs {want}",
                    out[i]
                );
            }
        }
    }

    #[test]
    fn scan_scores_deterministic_across_thread_counts() {
        let (n, d, bits) = (300usize, 48usize, 5u8);
        let mut rng = Rng::new(4100);
        let values: Vec<u8> = (0..n * d).map(|_| rng.below(1 << bits) as u8).collect();
        let packed = PackedCodes::pack(&values, bits);
        let r: Vec<f32> = rng.gaussian_vec(n);
        let q = rng.gaussian_vec(d);
        let mut a = vec![0f32; n];
        let mut b = vec![0f32; n];
        scan_scores_q(&q, &packed.data, bits, 0, n, &r, 1, &mut a);
        scan_scores_q(&q, &packed.data, bits, 0, n, &r, 8, &mut b);
        assert_eq!(a, b, "scan_scores_q must be bit-deterministic in threads");

        let rows = rng.gaussian_vec(n * d);
        let mut fa = vec![0f32; n];
        let mut fb = vec![0f32; n];
        scan_scores_f32(&q, &rows, n, 1, &mut fa);
        scan_scores_f32(&q, &rows, n, 8, &mut fb);
        assert_eq!(fa, fb, "scan_scores_f32 must be bit-deterministic in threads");
    }

    #[test]
    fn scan_scores_f32_matches_naive_dot() {
        let (n, d) = (9usize, 33usize);
        let mut rng = Rng::new(4200);
        let rows = rng.gaussian_vec(n * d);
        let q = rng.gaussian_vec(d);
        let mut out = vec![0f32; n];
        scan_scores_f32(&q, &rows, n, 2, &mut out);
        for i in 0..n {
            let want: f64 = q
                .iter()
                .zip(&rows[i * d..(i + 1) * d])
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            assert!((out[i] as f64 - want).abs() < 1e-4 * (1.0 + want.abs()), "row {i}");
        }
        // n == 0 is a no-op, not a panic
        scan_scores_f32(&q, &rows, 0, 2, &mut out);
        scan_scores_q(&q, &[], 4, 0, 0, &[], 2, &mut out);
    }

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0f32;
                for kk in 0..a.cols {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                *out.at_mut(i, j) = s;
            }
        }
        out
    }

    #[test]
    fn gemm_matches_naive_odd_shapes() {
        for (m, k, n) in [(1usize, 1usize, 1usize), (5, 7, 3), (13, 32, 17), (64, 50, 33)] {
            let a = random_matrix(m, k, (m * 100 + k) as u64);
            let b = random_matrix(k, n, (k * 100 + n) as u64);
            let mut out = vec![0f32; m * n];
            gemm(m, k, n, &a.data, &b.data, &mut out, 4);
            let want = naive_matmul(&a, &b);
            let got = Matrix::from_vec(m, n, out);
            assert!(got.rel_err(&want) < 1e-4, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn gemm_deterministic_across_thread_counts() {
        let a = random_matrix(37, 29, 21);
        let b = random_matrix(29, 41, 22);
        let mut o1 = vec![0f32; 37 * 41];
        let mut o8 = vec![0f32; 37 * 41];
        gemm(37, 29, 41, &a.data, &b.data, &mut o1, 1);
        gemm(37, 29, 41, &a.data, &b.data, &mut o8, 8);
        assert_eq!(o1, o8);
    }

    #[test]
    fn gemm_degenerate_dims() {
        let mut out = vec![0f32; 0];
        gemm(0, 4, 0, &[], &[0.0; 0], &mut out, 2);
        let a = vec![1.0f32, 2.0];
        let mut o = vec![0f32; 2];
        // k == 0: out unchanged
        gemm(2, 0, 1, &[], &[], &mut o, 2);
        assert_eq!(o, vec![0.0, 0.0]);
        let _ = a;
    }
}
