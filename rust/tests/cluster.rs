//! Loopback integration tests for the sharded router/worker cluster
//! (ISSUE 9): a real router over 1–3 real worker nodes — each a full
//! batcher + index behind its own `TcpListener` — no mocks anywhere.
//!
//! The wall, in order:
//! (a) the pure distributed decomposition — per-shard `scan_candidates`
//!     → global select → `exact_scores` → merge — equals a single-node
//!     `VectorStore::query` bit-for-bit, no sockets involved;
//! (b) the same contract END TO END over HTTP: rows added through the
//!     router, queries scatter-gathered across 2 workers, results
//!     byte-compared against a single-node store with the same rows;
//! (c) `POST /v1/generate` round-robins across healthy workers and
//!     relays worker responses verbatim;
//! (d) killing a worker mid-flight degrades explicitly (`degraded`,
//!     `failed_shards`) — never a hang or silent partial — and an
//!     all-dead collection answers 503 + `Retry-After`; a restarted
//!     worker is re-admitted by the prober;
//! (e) a draining worker keeps serving in-flight work but receives no
//!     new generate traffic, and nothing is dropped in the handoff;
//! (f) fleet `/v1/stats` reports per-worker state/queue depth and
//!     computes percentiles over the CONCATENATED latency windows
//!     (exactly equal to percentile-of-concatenation, never an average
//!     of per-worker percentiles);
//! (g) the committed `cluster_merge.json` golden vectors pin the merge
//!     order against the numpy mirror (`python/tests/test_cluster.py`).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use raana::cluster::{merge, Router, RouterConfig};
use raana::index::{top_indices, IndexConfig, SearchHit, VectorStore};
use raana::json::{self, Value};
use raana::model::synthetic_manifest;
use raana::net::{http_request, ClientConfig, HttpConfig, HttpServer};
use raana::quant::{LayerCalib, TrickConfig};
use raana::rng::Rng;
use raana::runtime::{native_init, PackedLayers};
use raana::serve::index::IndexServer;
use raana::serve::{ServeConfig, Server};

// ------------------------------------------------------------- harness

/// One in-process worker node: batcher + index + HTTP front-end, plus
/// the drain flag a real `raana worker` would flip on stdin EOF.
struct WorkerNode {
    server: Arc<Server>,
    index: Arc<IndexServer>,
    http: HttpServer,
    drain: Arc<AtomicBool>,
    addr: String,
}

impl WorkerNode {
    /// Start a worker on `addr` (use `"127.0.0.1:0"` for ephemeral).
    /// Every worker uses the SAME model seed and the default store
    /// config, so any two nodes quantize a given row identically — the
    /// precondition for bit-identical scatter-gather.
    fn start(addr: &str) -> WorkerNode {
        let manifest = synthetic_manifest("cluster-worker", 32, 1, 2, 64, 16, 256, 2);
        let params = native_init(&manifest, 17);
        let stats: Vec<LayerCalib> =
            manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; manifest.linears.len()];
        let packed =
            PackedLayers::quantize(&manifest, &params, &bits, &stats, &TrickConfig::none(), 1, 1)
                .unwrap();
        let index = Arc::new(
            IndexServer::with_embedder(
                IndexConfig::default(),
                None,
                manifest.clone(),
                params.clone(),
                Some(packed.clone()),
            )
            .unwrap(),
        );
        let server = Arc::new(
            Server::start_native_packed_with(manifest, params, packed, ServeConfig::default())
                .unwrap(),
        );
        let drain = Arc::new(AtomicBool::new(false));
        let http = HttpServer::bind_with_index(
            Arc::clone(&server),
            Some(Arc::clone(&index)),
            addr,
            HttpConfig { workers: 2, drain: Some(Arc::clone(&drain)), ..Default::default() },
        )
        .unwrap();
        let addr = format!("127.0.0.1:{}", http.local_addr().port());
        WorkerNode { server, index, http, drain, addr }
    }

    fn completions(&self) -> usize {
        self.server.stats().completions
    }

    /// Kill the node outright: listener closed, batcher gone — the
    /// "worker process died" failure the router must degrade around.
    fn kill(self) {
        self.http.shutdown().unwrap();
        drop(self.index);
        match Arc::try_unwrap(self.server) {
            Ok(s) => {
                s.shutdown().unwrap();
            }
            Err(_) => panic!("server still referenced after HTTP shutdown"),
        }
    }
}

/// Router over the given workers with test-speed probe/RPC deadlines.
fn start_router(workers: Vec<String>, shards: usize) -> Router {
    Router::bind(
        "127.0.0.1:0",
        RouterConfig {
            workers,
            shards,
            http_workers: 4,
            probe_interval_ms: 50,
            client: ClientConfig::timeout_ms(2000),
            ..Default::default()
        },
    )
    .unwrap()
}

fn raddr(router: &Router) -> String {
    format!("127.0.0.1:{}", router.local_addr().port())
}

/// Reserve an explicit loopback port (bind :0, read it back, release):
/// lets a test restart a "recovered" worker on the address the router
/// was configured with.
fn reserve_port() -> u16 {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let p = l.local_addr().unwrap().port();
    drop(l);
    p
}

fn vec_json(v: &[f32]) -> Value {
    json::arr(v.iter().map(|&x| json::num(x as f64)).collect())
}

fn add_body(rows: &[f32], d: usize) -> String {
    json::obj(vec![(
        "vectors",
        json::arr(rows.chunks_exact(d).map(vec_json).collect()),
    )])
    .to_json()
}

fn query_body(q: &[f32], k: usize, rf: usize) -> String {
    format!("{{\"vector\":{},\"k\":{k},\"rerank_factor\":{rf}}}", vec_json(q).to_json())
}

/// Parse a response's `results` into hits (ids exact, scores as the f64
/// the wire carried — f32 scores round-trip bit-exactly through the
/// JSON writer/parser, so `as f32` recovers the worker's exact value).
fn parse_results(v: &Value) -> Vec<SearchHit> {
    v.get("results")
        .and_then(Value::as_arr)
        .expect("results array")
        .iter()
        .map(|h| SearchHit {
            id: h.get("id").unwrap().as_f64().unwrap() as usize,
            score: h.get("score").unwrap().as_f64().unwrap() as f32,
        })
        .collect()
}

fn deterministic_rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * d).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

fn generate_body(prompt: &[i32], max_new_tokens: usize) -> String {
    format!("{{\"prompt\":{prompt:?},\"max_new_tokens\":{max_new_tokens},\"temperature\":0,\"seed\":0}}")
}

fn poll_until(what: &str, mut ok: impl FnMut() -> bool) {
    for _ in 0..400 {
        if ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

// ------------------------------------------- (a) pure decomposition

/// The distributed two-phase pipeline over real `VectorStore` shards —
/// no router, no sockets — must reproduce a single node bit-for-bit.
/// This is the determinism contract in its smallest executable form.
#[test]
fn sharded_stores_equal_single_node_bit_for_bit() {
    let (n, d, n_shards) = (57usize, 24usize, 3usize);
    let rows = deterministic_rows(n, d, 0xC1A5);
    let mut single = VectorStore::new(IndexConfig::default()).unwrap();
    single.add("docs", &rows, d, 1).unwrap();
    let mut shards: Vec<VectorStore> =
        (0..n_shards).map(|_| VectorStore::new(IndexConfig::default()).unwrap()).collect();
    for s in 0..n_shards {
        let slice: Vec<f32> = rows
            .chunks_exact(d)
            .enumerate()
            .filter(|(g, _)| merge::shard_of(*g, n_shards) == s)
            .flat_map(|(_, r)| r.iter().copied())
            .collect();
        shards[s].add("docs", &slice, d, 1).unwrap();
    }
    for (qi, (k, rf)) in [(7usize, 3usize), (1, 1), (12, 4), (60, 2)].iter().enumerate() {
        let q: Vec<f32> = deterministic_rows(1, d, 0xBEEF + qi as u64);
        let want = single.query("docs", &q, *k, *rf, 1).unwrap();

        let take = merge::global_take(*k, *rf, n);
        let per_shard: Vec<(usize, Vec<SearchHit>)> = (0..n_shards)
            .filter(|&s| merge::shard_rows(s, n_shards, n) > 0)
            .map(|s| {
                let (_, hits) = shards[s].scan_candidates("docs", &q, take, 1).unwrap();
                (s, hits)
            })
            .collect();
        let cands = merge::select_candidates(&per_shard, n_shards, take, n);
        let mut exact = Vec::new();
        for s in 0..n_shards {
            let locals: Vec<usize> = cands
                .iter()
                .filter(|c| merge::shard_of(c.id, n_shards) == s)
                .map(|c| merge::local_of(c.id, n_shards))
                .collect();
            if locals.is_empty() {
                continue;
            }
            for (l, h) in locals.iter().zip(shards[s].exact_scores("docs", &q, &locals).unwrap()) {
                assert_eq!(h.id, *l);
                exact.push(SearchHit { id: merge::global_of(s, *l, n_shards), score: h.score });
            }
        }
        let got = merge::merge_hits(exact, *k);
        assert_eq!(got.len(), want.len(), "k={k} rf={rf}");
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id, "id mismatch at k={k} rf={rf}");
            assert_eq!(
                g.score.to_bits(),
                w.score.to_bits(),
                "score bits differ for id {} at k={k} rf={rf}",
                g.id
            );
        }
    }
}

// ------------------------------------- (b) end-to-end over the wire

#[test]
fn scatter_gather_over_http_matches_single_node() {
    let (n, d) = (40usize, 16usize);
    let rows = deterministic_rows(n, d, 0x5EED);
    let mut single = VectorStore::new(IndexConfig::default()).unwrap();
    single.add("docs", &rows, d, 1).unwrap();

    let w0 = WorkerNode::start("127.0.0.1:0");
    let w1 = WorkerNode::start("127.0.0.1:0");
    let router = start_router(vec![w0.addr.clone(), w1.addr.clone()], 0);
    let ra = raddr(&router);

    // two batches through the router: exercises expect_first_id append
    // positions beyond a fresh collection
    let (a, b) = rows.split_at(n / 2 * d);
    for batch in [a, b] {
        let resp =
            http_request(&ra, "POST", "/v1/collections/docs/add", Some(&add_body(batch, d)))
                .unwrap();
        assert_eq!(resp.status, 200, "add: {}", resp.body_str().unwrap_or(""));
    }
    for (qi, (k, rf)) in [(7usize, 3usize), (1, 2), (10, 4)].iter().enumerate() {
        let q = deterministic_rows(1, d, 0xF00D + qi as u64);
        let resp =
            http_request(&ra, "POST", "/v1/collections/docs/query", Some(&query_body(&q, *k, *rf)))
                .unwrap();
        assert_eq!(resp.status, 200, "query: {}", resp.body_str().unwrap_or(""));
        let v = resp.json().unwrap();
        assert_eq!(v.get("degraded").and_then(Value::as_bool), Some(false));
        let got = parse_results(&v);
        let want = single.query("docs", &q, *k, *rf, 1).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.id, w.id, "cluster vs single-node id order (k={k})");
            assert_eq!(
                g.score.to_bits(),
                w.score.to_bits(),
                "score bits for id {} (k={k})",
                g.id
            );
        }
    }

    // typed router errors: embedding shapes are a worker affordance
    let resp = http_request(&ra, "POST", "/v1/collections/docs/add", Some(r#"{"texts":["x"]}"#))
        .unwrap();
    assert_eq!(resp.status, 400);
    assert!(resp.json().unwrap().get("error").is_some(), "uniform error shape");
    let resp =
        http_request(&ra, "POST", "/v1/collections/nope/query", Some(&query_body(&[0.0; 16], 3, 2)))
            .unwrap();
    assert_eq!(resp.status, 404);

    router.shutdown().unwrap();
    w0.kill();
    w1.kill();
}

// --------------------------------------------- (c) generate routing

#[test]
fn generate_round_robins_and_relays_verbatim() {
    let w0 = WorkerNode::start("127.0.0.1:0");
    let w1 = WorkerNode::start("127.0.0.1:0");
    let router = start_router(vec![w0.addr.clone(), w1.addr.clone()], 0);
    let ra = raddr(&router);

    // greedy decode on identical models: every worker produces the same
    // tokens, so the relayed body must equal a direct worker call
    let body = generate_body(&[10, 20, 30], 6);
    let direct = http_request(&w0.addr, "POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(direct.status, 200);
    let direct_tokens = direct.json().unwrap().get("tokens").unwrap().to_json();
    for _ in 0..4 {
        let routed = http_request(&ra, "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(routed.status, 200);
        let routed_tokens = routed.json().unwrap().get("tokens").unwrap().to_json();
        assert_eq!(routed_tokens, direct_tokens, "relay must not alter the completion");
    }
    assert!(
        w0.completions() >= 2 && w1.completions() >= 1,
        "round robin must spread load: w0={} w1={}",
        w0.completions(),
        w1.completions()
    );

    router.shutdown().unwrap();
    w0.kill();
    w1.kill();
}

// ---------------------------- (d) degradation, 503, re-admission

#[test]
fn killed_worker_degrades_explicitly_and_readmits_on_recovery() {
    let port1 = reserve_port();
    let w0 = WorkerNode::start("127.0.0.1:0");
    let w1 = WorkerNode::start(&format!("127.0.0.1:{port1}"));
    let router = start_router(vec![w0.addr.clone(), w1.addr.clone()], 0);
    let ra = raddr(&router);

    let (n, d) = (12usize, 8usize);
    let rows = deterministic_rows(n, d, 0xDEAD);
    let resp =
        http_request(&ra, "POST", "/v1/collections/docs/add", Some(&add_body(&rows, d))).unwrap();
    assert_eq!(resp.status, 200);

    // healthy baseline
    let q = deterministic_rows(1, d, 1);
    let body = query_body(&q, 4, 2);
    let resp = http_request(&ra, "POST", "/v1/collections/docs/query", Some(&body)).unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.json().unwrap().get("degraded").and_then(Value::as_bool), Some(false));

    // kill one worker: the very next query must degrade EXPLICITLY —
    // typed flag + failed shard list — not hang, not silently shrink
    w1.kill();
    let resp = http_request(&ra, "POST", "/v1/collections/docs/query", Some(&body)).unwrap();
    assert_eq!(resp.status, 200, "one live shard still answers");
    let v = resp.json().unwrap();
    assert_eq!(v.get("degraded").and_then(Value::as_bool), Some(true));
    let failed = v.get("failed_shards").and_then(Value::as_arr).unwrap();
    assert_eq!(failed.len(), 1, "exactly the dead worker's shard failed");
    assert!(!parse_results(&v).is_empty(), "surviving shard's rows still surface");

    // restart the worker on its configured address: the prober must
    // re-admit it without router intervention
    let w1b = WorkerNode::start(&format!("127.0.0.1:{port1}"));
    poll_until("prober re-admission", || {
        http_request(&ra, "GET", "/healthz", None)
            .ok()
            .and_then(|r| r.json().ok())
            .and_then(|v| v.get("workers_healthy").and_then(Value::as_f64))
            == Some(2.0)
    });
    // and the re-admitted worker takes generate traffic again
    let before = w1b.completions();
    for _ in 0..4 {
        let r = http_request(&ra, "POST", "/v1/generate", Some(&generate_body(&[5], 2))).unwrap();
        assert_eq!(r.status, 200);
    }
    assert!(w1b.completions() > before, "recovered worker back in rotation");

    router.shutdown().unwrap();
    w0.kill();
    w1b.kill();
}

#[test]
fn all_shards_dead_is_typed_503_with_retry_after() {
    let w0 = WorkerNode::start("127.0.0.1:0");
    let router = start_router(vec![w0.addr.clone()], 0);
    let ra = raddr(&router);

    let rows = deterministic_rows(6, 8, 3);
    let resp =
        http_request(&ra, "POST", "/v1/collections/docs/add", Some(&add_body(&rows, 8))).unwrap();
    assert_eq!(resp.status, 200);
    w0.kill();

    let resp =
        http_request(&ra, "POST", "/v1/collections/docs/query", Some(&query_body(&[0.5; 8], 3, 2)))
            .unwrap();
    assert_eq!(resp.status, 503, "no reachable shard must be a typed refusal");
    assert!(resp.json().unwrap().get("error").is_some(), "uniform error shape");
    assert!(
        resp.headers.iter().any(|(k, v)| k == "retry-after" && !v.is_empty()),
        "503 must carry Retry-After"
    );

    // generate with every worker dead: same typed refusal (the prober
    // condemns the worker after down_after failed probes)
    poll_until("worker condemned", || {
        http_request(&ra, "GET", "/healthz", None)
            .ok()
            .and_then(|r| r.json().ok())
            .and_then(|v| v.get("workers_healthy").and_then(Value::as_f64))
            == Some(0.0)
    });
    let resp = http_request(&ra, "POST", "/v1/generate", Some(&generate_body(&[5], 2))).unwrap();
    assert_eq!(resp.status, 503);
    assert!(resp.headers.iter().any(|(k, _)| k == "retry-after"));

    router.shutdown().unwrap();
}

// ------------------------------------------------- (e) graceful drain

#[test]
fn draining_worker_gets_no_new_generate_traffic_and_drops_nothing() {
    let w0 = WorkerNode::start("127.0.0.1:0");
    let w1 = WorkerNode::start("127.0.0.1:0");
    let router = start_router(vec![w0.addr.clone(), w1.addr.clone()], 0);
    let ra = raddr(&router);

    // worker 0 announces drain (what `raana worker` does on stdin EOF)
    w0.drain.store(true, Ordering::SeqCst);
    poll_until("router observes draining", || {
        http_request(&ra, "GET", "/v1/stats", None)
            .ok()
            .and_then(|r| r.json().ok())
            .and_then(|v| {
                v.get("per_worker").and_then(Value::as_arr).map(|ws| {
                    ws.iter()
                        .any(|w| w.get("state").and_then(Value::as_str) == Some("draining"))
                })
            })
            .unwrap_or(false)
    });
    let drained_before = w0.completions();
    // every request during the drain must still succeed — routed to the
    // remaining worker, none dropped, none duplicated
    for _ in 0..5 {
        let r = http_request(&ra, "POST", "/v1/generate", Some(&generate_body(&[7], 2))).unwrap();
        assert_eq!(r.status, 200, "drain must not drop requests");
    }
    assert_eq!(w0.completions(), drained_before, "draining worker got new work");
    assert!(w1.completions() >= 5, "surviving worker took the traffic");

    // drain cancelled: the worker is re-admitted (state machine, not a
    // one-way door)
    w0.drain.store(false, Ordering::SeqCst);
    poll_until("drain cancellation observed", || {
        http_request(&ra, "GET", "/healthz", None)
            .ok()
            .and_then(|r| r.json().ok())
            .and_then(|v| v.get("workers_healthy").and_then(Value::as_f64))
            == Some(2.0)
    });

    router.shutdown().unwrap();
    w0.kill();
    w1.kill();
}

// ---------------------------------------------------- (f) fleet stats

#[test]
fn fleet_stats_concatenate_windows_and_expose_per_worker_depth() {
    let w0 = WorkerNode::start("127.0.0.1:0");
    let w1 = WorkerNode::start("127.0.0.1:0");
    let router = start_router(vec![w0.addr.clone(), w1.addr.clone()], 0);
    let ra = raddr(&router);

    for _ in 0..6 {
        let r = http_request(&ra, "POST", "/v1/generate", Some(&generate_body(&[9], 2))).unwrap();
        assert_eq!(r.status, 200);
    }
    // traffic has fully completed: worker latency windows are static, so
    // the fleet percentiles must EXACTLY equal percentile-of-concatenation
    let mut all: Vec<f64> = Vec::new();
    for w in [&w0, &w1] {
        let v = http_request(&w.addr, "GET", "/v1/stats", None).unwrap().json().unwrap();
        all.extend(
            v.get("latencies_secs").and_then(Value::as_arr).unwrap().iter().filter_map(Value::as_f64),
        );
    }
    assert_eq!(all.len(), 6, "every completion lands in exactly one worker window");

    let v = http_request(&ra, "GET", "/v1/stats", None).unwrap().json().unwrap();
    assert_eq!(v.get("workers").and_then(Value::as_f64), Some(2.0));
    assert_eq!(v.get("workers_healthy").and_then(Value::as_f64), Some(2.0));
    assert_eq!(v.get("completions").and_then(Value::as_f64), Some(6.0));
    assert_eq!(v.get("latency_samples").and_then(Value::as_f64), Some(all.len() as f64));
    assert_eq!(
        v.get("p50_latency_secs").and_then(Value::as_f64),
        Some(raana::util::percentile(&all, 50.0)),
        "fleet p50 must be the percentile of the concatenated windows"
    );
    assert_eq!(
        v.get("p95_latency_secs").and_then(Value::as_f64),
        Some(raana::util::percentile(&all, 95.0)),
        "fleet p95 must be the percentile of the concatenated windows"
    );
    let per = v.get("per_worker").and_then(Value::as_arr).unwrap();
    assert_eq!(per.len(), 2);
    for w in per {
        assert_eq!(w.get("state").and_then(Value::as_str), Some("healthy"));
        assert_eq!(w.get("reachable").and_then(Value::as_bool), Some(true));
        assert!(w.get("queue_depth").and_then(Value::as_f64).is_some(), "per-worker queue depth");
    }

    router.shutdown().unwrap();
    w0.kill();
    w1.kill();
}

// ------------------------------------------------ (g) golden vectors

fn load_golden(name: &str) -> Value {
    let path: PathBuf =
        [env!("CARGO_MANIFEST_DIR"), "rust", "tests", "vectors", name].iter().collect();
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!("missing golden vectors {} ({e}); regenerate with python/tests/gen_vectors.py", path.display())
    });
    json::parse(&text).expect("golden vectors must parse")
}

fn golden_f32s(v: &Value, key: &str) -> Vec<f32> {
    v.get(key)
        .and_then(Value::as_arr)
        .unwrap_or_else(|| panic!("golden key {key}"))
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

fn golden_usizes(v: &Value, key: &str) -> Vec<usize> {
    v.get(key)
        .and_then(Value::as_arr)
        .unwrap_or_else(|| panic!("golden key {key}"))
        .iter()
        .map(|x| x.as_f64().unwrap() as usize)
        .collect()
}

fn golden_hits(v: &Value) -> Vec<SearchHit> {
    v.as_arr()
        .expect("hit list")
        .iter()
        .map(|h| SearchHit {
            id: h.get("id").unwrap().as_f64().unwrap() as usize,
            score: h.get("score").unwrap().as_f64().unwrap() as f32,
        })
        .collect()
}

/// The full merge pipeline over the committed fixture: per-shard local
/// top-take from the estimated scores (via the SAME `top_indices` the
/// worker scan uses), global candidate selection, exact-score merge —
/// each stage compared against the numpy-generated expectation.
#[test]
fn golden_cluster_merge_pins_the_pipeline() {
    let doc = load_golden("cluster_merge.json");
    let n = doc.get("n").unwrap().as_f64().unwrap() as usize;
    let n_shards = doc.get("n_shards").unwrap().as_f64().unwrap() as usize;
    let k = doc.get("k").unwrap().as_f64().unwrap() as usize;
    let rf = doc.get("rerank_factor").unwrap().as_f64().unwrap() as usize;
    let est = golden_f32s(&doc, "est");
    let exact = golden_f32s(&doc, "exact");
    assert_eq!(est.len(), n);
    assert_eq!(exact.len(), n);

    let take = merge::global_take(k, rf, n);
    assert_eq!(take, doc.get("take").unwrap().as_f64().unwrap() as usize);

    // per-shard local top-take over each shard's est slice
    let expected_shards = doc.get("per_shard_candidates").unwrap().as_arr().unwrap();
    let mut per_shard: Vec<(usize, Vec<SearchHit>)> = Vec::new();
    for s in 0..n_shards {
        let local_est: Vec<f32> = (0..merge::shard_rows(s, n_shards, n))
            .map(|l| est[merge::global_of(s, l, n_shards)])
            .collect();
        let hits: Vec<SearchHit> = top_indices(&local_est, take)
            .into_iter()
            .map(|l| SearchHit { id: l, score: local_est[l] })
            .collect();
        let want = golden_hits(&expected_shards[s]);
        assert_eq!(hits.len(), want.len(), "shard {s} candidate count");
        for (g, w) in hits.iter().zip(&want) {
            assert_eq!(g.id, w.id, "shard {s} local order");
            assert_eq!(g.score.to_bits(), w.score.to_bits(), "shard {s} est score bits");
        }
        per_shard.push((s, hits));
    }

    // global candidate selection
    let cands = merge::select_candidates(&per_shard, n_shards, take, n);
    let got_gids: Vec<usize> = cands.iter().map(|c| c.id).collect();
    assert_eq!(got_gids, golden_usizes(&doc, "selected_gids"), "global selection order");

    // exact-score merge
    let exact_hits: Vec<SearchHit> =
        cands.iter().map(|c| SearchHit { id: c.id, score: exact[c.id] }).collect();
    let merged = merge::merge_hits(exact_hits, k);
    let want = golden_hits(doc.get("merged").unwrap());
    assert_eq!(merged.len(), want.len());
    for (g, w) in merged.iter().zip(&want) {
        assert_eq!(g.id, w.id, "merged order");
        assert_eq!(g.score.to_bits(), w.score.to_bits(), "merged score bits");
    }
}
