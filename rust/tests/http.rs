//! Loopback integration tests for the HTTP serving front-end (ISSUE 3):
//! a real `TcpListener` on an ephemeral port, real sockets, the packed
//! native demo model behind the batcher — no mocks anywhere.
//!
//! The wall, in order:
//! (a) greedy generation over `POST /v1/generate` is bit-identical to
//!     in-process `Server::submit`;
//! (b) streamed chunks reassemble to exactly the non-streamed response;
//! (c) a full admission queue answers 429 and does NOT silently queue;
//! (d) dropping the client connection mid-generation frees the KV lane
//!     (the next request admits);
//! (e) `/healthz` and `/v1/stats` answer while generation is in flight;
//! plus protocol-robustness cases (bad JSON, bad routes, oversized
//! bodies, out-of-vocab prompts) that must map to clean 4xx responses.
//!
//! The retrieval wall (ISSUE 5) rides the same loopback setup:
//! (f) embed → add → query round-trips over the wire, self-retrieval
//!     included, and `GET /v1/collections` reports real accounting;
//! (g) EVERY error path — 400/404/405/408/413/429/503, generate and
//!     index endpoints alike — answers the one JSON shape
//!     `{"error": "..."}`, and 405 responses carry an `Allow:` header;
//! (h) servers bound without an index answer 404 on the index paths.
//!
//! The robustness wall (ISSUE 6) extends it:
//! (i) a slow-loris client that stalls mid-head gets a typed 408, not a
//!     worker pinned forever;
//! (j) 429 and 503 responses carry `Retry-After`, and the bounded
//!     `http_request_retry` client honours it;
//! (k) a batcher panic fails in-flight requests with a typed error and
//!     flips `/healthz` unhealthy — submitters never hang.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use raana::json;
use raana::model::synthetic_manifest;
use raana::net::{http_request, http_request_retry, HttpConfig, HttpServer};
use raana::quant::{LayerCalib, TrickConfig};
use raana::runtime::{native_init, PackedLayers};
use raana::serve::{ServeConfig, Server};

/// Packed demo fixture (mirrors `serve::tests::packed_fixture`): vocab
/// 256, tiny dims so generation is fast, `eval_batch` KV lanes.
fn packed_server(name: &str, seq_len: usize, eval_batch: usize, cfg: ServeConfig) -> Arc<Server> {
    let manifest = synthetic_manifest(name, 32, 1, 2, 64, seq_len, 256, eval_batch);
    let params = native_init(&manifest, 17);
    let stats: Vec<LayerCalib> =
        manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
    let bits = vec![4u8; manifest.linears.len()];
    let packed = PackedLayers::quantize(
        &manifest, &params, &bits, &stats, &TrickConfig::none(), 1, 1,
    )
    .unwrap();
    Arc::new(Server::start_native_packed_with(manifest, params, packed, cfg).unwrap())
}

/// Bind with the `max_new_tokens` clamp lifted: the lane-pinning tests
/// rely on effectively-endless generations, which the default cap
/// (correctly) prevents.
fn bind_uncapped(server: &Arc<Server>, workers: usize) -> HttpServer {
    HttpServer::bind_with(
        Arc::clone(server),
        "127.0.0.1:0",
        HttpConfig { workers, max_new_tokens_cap: usize::MAX, ..Default::default() },
    )
    .unwrap()
}

fn shutdown_all(http: HttpServer, server: Arc<Server>) -> raana::serve::ServerStats {
    http.shutdown().unwrap();
    match Arc::try_unwrap(server) {
        Ok(s) => s.shutdown().unwrap(),
        Err(_) => panic!("server still referenced after HTTP shutdown"),
    }
}

fn generate_body(prompt: &[i32], max_new_tokens: usize, stream: bool) -> String {
    format!(
        "{{\"prompt\":{:?},\"max_new_tokens\":{max_new_tokens},\"temperature\":0,\
         \"seed\":0,\"stream\":{stream}}}",
        prompt
    )
}

/// Block until the batcher has sampled at least `min_tokens` (proof that a
/// request owns a KV lane and is generating, not merely queued — the HTTP
/// response head is written at submission time, so reading it proves
/// nothing about lane ownership).
fn wait_generating(server: &Server, min_tokens: usize) {
    for _ in 0..6000 {
        if server.stats().tokens_generated >= min_tokens {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("server never started generating");
}

fn tokens_of(v: &json::Value) -> Vec<i32> {
    v.get("tokens")
        .and_then(|t| t.as_arr())
        .unwrap_or(&[])
        .iter()
        .filter_map(|x| x.as_f64())
        .map(|f| f as i32)
        .collect()
}

// ------------------------------------------------------------- (a) parity

#[test]
fn http_greedy_generation_matches_in_process_submit() {
    let server = packed_server("http-parity", 8, 2, ServeConfig::default());
    let http = HttpServer::bind(Arc::clone(&server), "127.0.0.1:0", 2).unwrap();
    let addr = http.local_addr().to_string();

    let prompt = vec![10i32, 20, 30];
    // in-process reference (greedy: deterministic, so ids don't matter)
    let (_, rx) = server.submit(prompt.clone(), 6, 0.0, 0).unwrap();
    let want = rx.recv().unwrap().tokens;

    let resp =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&prompt, 6, false)))
            .unwrap();
    assert_eq!(resp.status, 200, "body: {:?}", resp.body_str());
    let v = resp.json().unwrap();
    assert_eq!(
        tokens_of(&v),
        want,
        "HTTP greedy generation must be bit-identical to Server::submit"
    );
    assert_eq!(v.req_usize("steps").unwrap(), 6);
    assert!(v.req("latency_secs").unwrap().as_f64().unwrap() >= 0.0);

    let stats = shutdown_all(http, server);
    assert_eq!(stats.completions, 2);
}

// --------------------------------------------------- (b) stream reassembly

#[test]
fn streamed_chunks_reassemble_to_nonstreamed_response() {
    let server = packed_server("http-stream", 8, 1, ServeConfig::default());
    let http = HttpServer::bind(Arc::clone(&server), "127.0.0.1:0", 2).unwrap();
    let addr = http.local_addr().to_string();
    let prompt = vec![5i32, 6, 7];

    let plain =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&prompt, 5, false)))
            .unwrap();
    assert_eq!(plain.status, 200);
    let want = tokens_of(&plain.json().unwrap());
    assert_eq!(want.len(), 5);

    let streamed =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&prompt, 5, true)))
            .unwrap();
    assert_eq!(streamed.status, 200);
    // one chunk per token event + one final done chunk
    assert_eq!(streamed.chunks.len(), 6, "5 token events + done");
    let mut from_events = Vec::new();
    let mut done_tokens = None;
    for (i, chunk) in streamed.chunks.iter().enumerate() {
        let line = std::str::from_utf8(chunk).unwrap();
        let v = json::parse(line.trim_end()).unwrap();
        if v.get("done").is_some() {
            assert_eq!(i, streamed.chunks.len() - 1, "done must be the last chunk");
            done_tokens = Some(tokens_of(&v));
        } else {
            assert_eq!(v.req_usize("index").unwrap(), from_events.len());
            from_events.push(v.req("token").unwrap().as_f64().unwrap() as i32);
        }
    }
    assert_eq!(from_events, want, "streamed tokens != non-streamed tokens");
    assert_eq!(done_tokens.expect("final done chunk"), want);

    shutdown_all(http, server);
}

// ------------------------------------------------------ (c) 429 backpressure

#[test]
fn full_admission_queue_answers_429_and_does_not_queue() {
    // one lane, queue capacity 1
    let server =
        packed_server("http-429", 8, 1, ServeConfig { max_queue: 1, ..Default::default() });
    let http = bind_uncapped(&server, 4);
    let addr = http.local_addr().to_string();

    // occupy the lane with an effectively-endless streamed request; the
    // first chunk proves it was admitted out of the queue
    let mut lane = TcpStream::connect(&addr).unwrap();
    let body = generate_body(&[1], 1_000_000, true);
    write!(
        lane,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    lane.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut first = [0u8; 1];
    lane.read_exact(&mut first).unwrap(); // response started
    wait_generating(&server, 1); // and the request owns the lane

    // fill the queue (in-process, so it stays queued behind the lane)
    let queued = server.submit(vec![2], 2, 0.0, 0).unwrap();
    assert_eq!(server.queue_depth(), 1);

    // over HTTP: the third request must be refused with 429...
    let resp =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[3], 2, false)))
            .unwrap();
    assert_eq!(resp.status, 429, "body: {:?}", resp.body_str());
    assert!(resp.body_str().unwrap().contains("queue"), "{:?}", resp.body_str());
    assert_eq!(header_of(&resp, "retry-after"), Some("1"), "429 must carry Retry-After");
    // ...and NOT silently queued
    assert_eq!(server.queue_depth(), 1, "rejected request must not enter the queue");

    // free the lane (client disconnect) so shutdown can drain
    drop(lane);
    let queued_done = queued.1.recv_timeout(Duration::from_secs(60));
    assert!(queued_done.is_ok(), "queued request must complete once the lane frees");
    let stats = shutdown_all(http, server);
    assert!(stats.cancelled >= 1, "dropped lane connection must count as cancelled");
}

// -------------------------------------------- (d) disconnect frees the lane

#[test]
fn dropping_client_connection_mid_generation_frees_the_lane() {
    let server = packed_server("http-drop", 8, 1, ServeConfig::default());
    let http = bind_uncapped(&server, 4);
    let addr = http.local_addr().to_string();

    // start an effectively-endless streamed generation, read a few bytes
    // of it (it is really running), then drop the socket
    let mut conn = TcpStream::connect(&addr).unwrap();
    let body = generate_body(&[9, 8], 1_000_000, true);
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut some = [0u8; 64];
    conn.read_exact(&mut some).unwrap();
    wait_generating(&server, 1);
    drop(conn);

    // the single lane must come free: a fresh request completes. The
    // server only notices at its next chunk write, so allow retries on
    // queueing but insist the whole thing resolves.
    let resp =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[4, 5], 3, false)))
            .unwrap();
    assert_eq!(resp.status, 200, "body: {:?}", resp.body_str());
    assert_eq!(tokens_of(&resp.json().unwrap()).len(), 3);

    let stats = shutdown_all(http, server);
    assert!(stats.cancelled >= 1, "disconnect must cancel, got {stats:?}");
    assert_eq!(stats.completions, 1);
}

#[test]
fn dropping_nonstreaming_client_also_frees_the_lane() {
    // non-streaming responses write nothing until completion, so the
    // disconnect is detected by the EOF probe rather than a chunk write
    let server = packed_server("http-drop-plain", 8, 1, ServeConfig::default());
    let http = bind_uncapped(&server, 4);
    let addr = http.local_addr().to_string();

    let conn = TcpStream::connect(&addr).unwrap();
    let body = generate_body(&[3, 1], 1_000_000, false);
    write!(
        &conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    wait_generating(&server, 1);
    drop(conn);

    let resp =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[6], 2, false)))
            .unwrap();
    assert_eq!(resp.status, 200, "body: {:?}", resp.body_str());
    let stats = shutdown_all(http, server);
    assert!(stats.cancelled >= 1, "EOF probe must cancel, got {stats:?}");
    assert_eq!(stats.completions, 1);
}

#[test]
fn busy_worker_pool_refuses_generate_but_keeps_cheap_endpoints() {
    // a single connection worker, pinned by an endless stream: further
    // generate requests must get a real 503 (never silent pool queueing),
    // while /healthz and /v1/stats keep answering via overflow handlers —
    // liveness probes must not fail on a busy-but-healthy server
    let server = packed_server("http-busy", 8, 2, ServeConfig::default());
    let http = bind_uncapped(&server, 1);
    let addr = http.local_addr().to_string();

    let conn = TcpStream::connect(&addr).unwrap();
    let body = generate_body(&[2], 1_000_000, true);
    write!(
        &conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    wait_generating(&server, 1);

    let refused =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[4], 2, false)))
            .unwrap();
    assert_eq!(refused.status, 503, "pinned pool must refuse generation");
    assert_eq!(header_of(&refused, "retry-after"), Some("1"), "503 must carry Retry-After");
    let health = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(health.status, 200, "liveness must survive a pinned pool");
    let stats = http_request(&addr, "GET", "/v1/stats", None).unwrap();
    assert_eq!(stats.status, 200, "stats must survive a pinned pool");

    // freeing the worker restores generation (detection happens at the
    // next chunk write, so the retry client absorbs the 503 window)
    drop(conn);
    let resp =
        http_request_retry(&addr, "POST", "/v1/generate", Some(&generate_body(&[5], 1, false)), 60)
            .unwrap();
    assert_eq!(resp.status, 200, "worker never came back after client disconnect");
    shutdown_all(http, server);
}

// --------------------------------------------- (i) slow-loris typed 408

#[test]
fn slow_loris_client_gets_typed_408() {
    let server = packed_server("http-loris", 8, 1, ServeConfig::default());
    let http = HttpServer::bind_with(
        Arc::clone(&server),
        "127.0.0.1:0",
        HttpConfig {
            workers: 2,
            max_new_tokens_cap: usize::MAX,
            read_timeout_ms: 200,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = http.local_addr().to_string();

    // stall mid-request-line: the server must not wait forever
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.write_all(b"POST /v1/gen").unwrap();
    let resp = raana::net::read_response(&conn).unwrap();
    assert_eq!(resp.status, 408, "stalled head must answer 408");
    let msg = assert_error_shape(&resp);
    assert!(msg.contains("timed out"), "{msg}");

    // stall mid-body: head complete, Content-Length never delivered
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.write_all(b"POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: 64\r\n\r\n{\"pro")
        .unwrap();
    let resp = raana::net::read_response(&conn).unwrap();
    assert_eq!(resp.status, 408, "stalled body must answer 408");
    assert_error_shape(&resp);

    // a prompt client is unaffected by the short timeout
    let health = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(health.status, 200);
    shutdown_all(http, server);
}

// ------------------------------- (k) batcher panic: typed failure, not hang

#[test]
fn batcher_panic_flips_health_and_fails_requests_typed() {
    let server = packed_server("http-panic", 8, 2, ServeConfig::default());
    let http = bind_uncapped(&server, 4);
    let addr = http.local_addr().to_string();

    // an in-flight non-streaming request: nothing is written until
    // completion, so the typed 500 is observable after the panic
    let conn = TcpStream::connect(&addr).unwrap();
    let body = generate_body(&[1, 2], 1_000_000, false);
    write!(
        &conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    wait_generating(&server, 1);

    server.inject_batcher_panic();

    // the in-flight request must fail with the typed abort — never hang
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let resp = raana::net::read_response(&conn).unwrap();
    assert_eq!(resp.status, 500, "body: {:?}", resp.body_str());
    let msg = assert_error_shape(&resp);
    assert!(msg.contains("aborted"), "{msg}");

    // /healthz must flip unhealthy once the worker has unwound
    let mut unhealthy = false;
    for _ in 0..600 {
        let h = http_request(&addr, "GET", "/healthz", None).unwrap();
        if h.json().unwrap().get("running").and_then(|b| b.as_bool()) == Some(false) {
            unhealthy = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(unhealthy, "healthz must report running:false after a batcher panic");

    // new generate requests are refused with a typed 503, not queued
    let refused =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[3], 2, false)))
            .unwrap();
    assert_eq!(refused.status, 503, "body: {:?}", refused.body_str());
    assert_error_shape(&refused);

    http.shutdown().unwrap();
    match Arc::try_unwrap(server) {
        Ok(s) => {
            s.shutdown().expect_err("shutdown must surface the batcher panic");
        }
        Err(_) => panic!("server still referenced after HTTP shutdown"),
    }
}

#[test]
fn max_new_tokens_is_clamped_server_side() {
    let server = packed_server("http-cap", 8, 1, ServeConfig::default());
    let http = HttpServer::bind_with(
        Arc::clone(&server),
        "127.0.0.1:0",
        HttpConfig { workers: 2, max_new_tokens_cap: 5, ..Default::default() },
    )
    .unwrap();
    let addr = http.local_addr().to_string();
    // a request asking for a billion tokens completes with the cap's worth
    let body = generate_body(&[1, 2], 1_000_000_000, false);
    let resp = http_request(&addr, "POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(resp.status, 200, "body: {:?}", resp.body_str());
    assert_eq!(tokens_of(&resp.json().unwrap()).len(), 5, "generation must be clamped");
    let stats = shutdown_all(http, server);
    assert_eq!(stats.completions, 1);
}

// ------------------------------------------- (e) health + stats in flight

#[test]
fn healthz_and_stats_respond_while_generation_is_in_flight() {
    let server = packed_server("http-live", 8, 1, ServeConfig::default());
    let http = bind_uncapped(&server, 4);
    let addr = http.local_addr().to_string();

    // pin the lane with a long streamed generation
    let mut conn = TcpStream::connect(&addr).unwrap();
    let body = generate_body(&[7], 1_000_000, true);
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut some = [0u8; 64];
    conn.read_exact(&mut some).unwrap();

    let health = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(health.status, 200);
    let hv = health.json().unwrap();
    assert_eq!(hv.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(hv.get("running").unwrap().as_bool(), Some(true));

    // stats must show live progress: tokens generated, zero completions
    let mut live_tokens = 0usize;
    for _ in 0..100 {
        let stats = http_request(&addr, "GET", "/v1/stats", None).unwrap();
        assert_eq!(stats.status, 200);
        let sv = stats.json().unwrap();
        assert_eq!(sv.req_usize("completions").unwrap(), 0);
        live_tokens = sv.req_usize("tokens_generated").unwrap();
        if live_tokens > 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(live_tokens > 0, "/v1/stats never showed in-flight progress");

    drop(conn);
    shutdown_all(http, server);
}

// ------------------------------------------------- protocol robustness wall

#[test]
fn hostile_requests_get_clean_4xx_responses() {
    let server = packed_server("http-hostile", 8, 1, ServeConfig::default());
    let http = HttpServer::bind(Arc::clone(&server), "127.0.0.1:0", 2).unwrap();
    let addr = http.local_addr().to_string();

    // malformed JSON body
    let r = http_request(&addr, "POST", "/v1/generate", Some("{not json")).unwrap();
    assert_eq!(r.status, 400);
    // nesting bomb flows through the hardened parser as a 400, not a crash
    let bomb = "[".repeat(50_000);
    let r = http_request(&addr, "POST", "/v1/generate", Some(&bomb)).unwrap();
    assert_eq!(r.status, 400);
    // wrong types
    let r = http_request(&addr, "POST", "/v1/generate", Some("{\"prompt\":\"hi\"}")).unwrap();
    assert_eq!(r.status, 400);
    // out-of-vocab prompt token: refused, and the server survives
    let r = http_request(&addr, "POST", "/v1/generate", Some("{\"prompt\":[70000]}")).unwrap();
    assert_eq!(r.status, 400, "body: {:?}", r.body_str());
    // unknown route / method
    let r = http_request(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(r.status, 404);
    let r = http_request(&addr, "DELETE", "/v1/generate", None).unwrap();
    assert_eq!(r.status, 405);
    // raw garbage instead of HTTP
    {
        let mut conn = TcpStream::connect(&addr).unwrap();
        conn.write_all(b"\x00\x01\x02 garbage\r\n\r\n").unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut out = Vec::new();
        let _ = conn.read_to_end(&mut out); // server answers 400 or closes
    }
    // oversized declared body
    {
        let mut conn = TcpStream::connect(&addr).unwrap();
        write!(
            conn,
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: 999999999\r\n\r\n"
        )
        .unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let resp = raana::net::read_response(&conn).unwrap();
        assert_eq!(resp.status, 413, "over-cap body is Payload Too Large, not generic 400");
    }

    // after all of that the server still serves valid traffic
    let r = http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[1, 2], 2, false)))
        .unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(tokens_of(&r.json().unwrap()).len(), 2);

    let stats = shutdown_all(http, server);
    assert_eq!(stats.completions, 1);
}

#[test]
fn stats_report_kv_cache_economics() {
    // a 4-bit quantized-KV server must expose its cache economics on
    // /v1/stats: effective bits, bytes per lane, pool size + occupancy
    let server = packed_server(
        "http-kvq",
        8,
        2,
        ServeConfig { kv: raana::kvq::KvqPolicy::Uniform(4), ..Default::default() },
    );
    let http = bind_uncapped(&server, 4);
    let addr = http.local_addr().to_string();

    // pin one lane so lanes_active has something to show
    let mut conn = TcpStream::connect(&addr).unwrap();
    let body = generate_body(&[3], 1_000_000, true);
    write!(
        conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut some = [0u8; 64];
    conn.read_exact(&mut some).unwrap();
    wait_generating(&server, 1);

    let resp = http_request(&addr, "GET", "/v1/stats", None).unwrap();
    assert_eq!(resp.status, 200);
    let v = resp.json().unwrap();
    assert_eq!(v.req("kv_bits").unwrap().as_f64().unwrap(), 4.0);
    assert!(v.req_usize("kv_bytes_per_lane").unwrap() > 0);
    assert_eq!(v.req_usize("lanes").unwrap(), 2);
    let mut active = 0;
    for _ in 0..200 {
        let v = http_request(&addr, "GET", "/v1/stats", None).unwrap().json().unwrap();
        active = v.req_usize("lanes_active").unwrap();
        if active >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(active >= 1, "an in-flight request must show as an active lane");
    // sanity: dense servers report 32-bit lanes
    drop(conn);
    shutdown_all(http, server);

    let dense = packed_server("http-kvq-dense", 8, 1, ServeConfig::default());
    let http = HttpServer::bind(Arc::clone(&dense), "127.0.0.1:0", 2).unwrap();
    let addr = http.local_addr().to_string();
    // one request forces a publish round; poll (the publish races the
    // completion by a scheduling round)
    let _ = http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[1], 1, false)))
        .unwrap();
    let mut bits = 0.0;
    for _ in 0..200 {
        let v = http_request(&addr, "GET", "/v1/stats", None).unwrap().json().unwrap();
        bits = v.req("kv_bits").unwrap().as_f64().unwrap();
        if bits > 0.0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(bits, 32.0, "dense servers report 32-bit KV lanes");
    shutdown_all(http, dense);
}

// --------------------------------------------- (f)(g)(h) retrieval wall

use raana::index::IndexConfig;
use raana::serve::index::IndexServer;

/// Index fixture sharing the demo-model recipe: 4-bit packed weights
/// behind the embed path, 8-bit (default) collection codes.
fn index_fixture(seed: u64) -> Arc<IndexServer> {
    let manifest = synthetic_manifest("http-index", 32, 1, 2, 64, 16, 256, 1);
    let params = native_init(&manifest, seed);
    let stats: Vec<LayerCalib> =
        manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
    let bits = vec![4u8; manifest.linears.len()];
    let packed = PackedLayers::quantize(
        &manifest, &params, &bits, &stats, &TrickConfig::none(), seed, 1,
    )
    .unwrap();
    Arc::new(
        IndexServer::with_embedder(IndexConfig::default(), None, manifest, params, Some(packed))
            .unwrap(),
    )
}

fn bind_indexed(server: &Arc<Server>, index: &Arc<IndexServer>, workers: usize) -> HttpServer {
    HttpServer::bind_with_index(
        Arc::clone(server),
        Some(Arc::clone(index)),
        "127.0.0.1:0",
        HttpConfig { workers, max_new_tokens_cap: usize::MAX, ..Default::default() },
    )
    .unwrap()
}

/// The one error contract: a JSON object whose single key is a
/// non-empty string `error`. Returns the message for spot checks.
fn assert_error_shape(resp: &raana::net::HttpResponse) -> String {
    let v = resp.json().unwrap_or_else(|e| {
        panic!("status {} body must be JSON, got {:?}: {e}", resp.status, resp.body_str())
    });
    let msg = v
        .get("error")
        .and_then(|m| m.as_str())
        .unwrap_or_else(|| panic!("status {} body must carry 'error': {:?}", resp.status, v));
    assert!(!msg.is_empty(), "error message must be non-empty");
    msg.to_string()
}

fn header_of<'a>(resp: &'a raana::net::HttpResponse, name: &str) -> Option<&'a str> {
    resp.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

#[test]
fn index_embed_add_query_flow_over_http() {
    let server = packed_server("http-ix-flow", 8, 1, ServeConfig::default());
    let index = index_fixture(23);
    let http = bind_indexed(&server, &index, 2);
    let addr = http.local_addr().to_string();

    // embed: unit-norm vector of the model width
    let r = http_request(&addr, "POST", "/v1/embed", Some(r#"{"text":"hello world"}"#)).unwrap();
    assert_eq!(r.status, 200, "body: {:?}", r.body_str());
    let ev = r.json().unwrap();
    assert_eq!(ev.req_usize("dim").unwrap(), 32);
    let emb: Vec<f64> = ev
        .get("embedding")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    assert_eq!(emb.len(), 32);
    let norm: f64 = emb.iter().map(|x| x * x).sum::<f64>().sqrt();
    assert!((norm - 1.0).abs() < 1e-4, "embedding must be unit-norm, got {norm}");

    // add three documents server-side (texts are embedded for us)
    let r = http_request(
        &addr,
        "POST",
        "/v1/collections/docs/add",
        Some(r#"{"texts":["alpha doc one","beta doc two","gamma doc three"]}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200, "body: {:?}", r.body_str());
    let av = r.json().unwrap();
    assert_eq!(av.req_usize("count").unwrap(), 3);
    let ids: Vec<usize> = av
        .get("ids")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_usize().unwrap())
        .collect();
    assert_eq!(ids, vec![0, 1, 2]);

    // add one raw vector (client-supplied embedding)
    let vec_body = format!(r#"{{"vectors":[{}]}}"#, ev.get("embedding").unwrap().to_json());
    let r =
        http_request(&addr, "POST", "/v1/collections/docs/add", Some(&vec_body)).unwrap();
    assert_eq!(r.status, 200, "body: {:?}", r.body_str());
    assert_eq!(r.json().unwrap().req_usize("count").unwrap(), 1);

    // self-retrieval through the wire: the re-embedded text is identical,
    // so after the exact rerank it must rank first with cosine ~1
    let r = http_request(
        &addr,
        "POST",
        "/v1/collections/docs/query",
        Some(r#"{"text":"beta doc two","k":2}"#),
    )
    .unwrap();
    assert_eq!(r.status, 200, "body: {:?}", r.body_str());
    let qv = r.json().unwrap();
    let results = qv.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].req_usize("id").unwrap(), 1, "own text must rank first");
    let score = results[0].req("score").unwrap().as_f64().unwrap();
    assert!((score - 1.0).abs() < 1e-3, "cosine self-score ~1, got {score}");

    // query by raw vector hits the raw-vector row (id 3, same embedding
    // as "hello world")
    let qbody = format!(r#"{{"vector":{},"k":1}}"#, ev.get("embedding").unwrap().to_json());
    let r = http_request(&addr, "POST", "/v1/collections/docs/query", Some(&qbody)).unwrap();
    assert_eq!(r.status, 200);
    let rv = r.json().unwrap();
    let top = &rv.get("results").unwrap().as_arr().unwrap()[0];
    assert_eq!(top.req_usize("id").unwrap(), 3);

    // accounting surface: rows, bits, scan bytes/row, counters
    let r = http_request(&addr, "GET", "/v1/collections", None).unwrap();
    assert_eq!(r.status, 200);
    let cv = r.json().unwrap();
    assert_eq!(cv.req_usize("rows").unwrap(), 4);
    assert_eq!(cv.req_usize("embed_dim").unwrap(), 32);
    assert!(cv.req_usize("embeds").unwrap() >= 5, "3 texts + 1 embed + 1 query text");
    assert_eq!(cv.req_usize("queries").unwrap(), 2);
    let cols = cv.get("collections").unwrap().as_arr().unwrap();
    assert_eq!(cols.len(), 1);
    assert_eq!(cols[0].req_str("name").unwrap(), "docs");
    assert_eq!(cols[0].req_usize("rows").unwrap(), 4);
    assert_eq!(cols[0].req_usize("dim").unwrap(), 32);
    assert_eq!(cols[0].req_usize("bits").unwrap(), 8);
    assert_eq!(cols[0].req_str("metric").unwrap(), "cosine");
    // 8-bit scan payload: d + 4 rescale bytes per row
    assert_eq!(cols[0].req_usize("bytes_per_row").unwrap(), 36);
    assert_eq!(cols[0].req_usize("exact_bytes").unwrap(), 4 * 32 * 4);

    shutdown_all(http, server);
}

#[test]
fn every_error_path_shares_one_json_shape_with_allow_on_405() {
    // single lane, single connection worker, one-deep admission queue:
    // enough to walk 400/404/405/413/429/503 (+ the index endpoints)
    // through real sockets and assert the one {"error": ...} shape
    let server = packed_server(
        "http-shapes",
        8,
        1,
        ServeConfig { max_queue: 1, ..Default::default() },
    );
    let index = index_fixture(29);
    let http = bind_indexed(&server, &index, 1);
    let addr = http.local_addr().to_string();

    // --- 404: unknown route, unknown collection verb, missing collection
    let r = http_request(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(r.status, 404);
    assert_error_shape(&r);
    let r = http_request(&addr, "POST", "/v1/collections/docs/compact", Some("{}")).unwrap();
    assert_eq!(r.status, 404);
    assert_error_shape(&r);
    let r = http_request(
        &addr,
        "POST",
        "/v1/collections/missing/query",
        Some(r#"{"vector":[1,2]}"#),
    )
    .unwrap();
    assert_eq!(r.status, 404, "missing collection is a 404: {:?}", r.body_str());
    assert_error_shape(&r);

    // --- 405 with Allow on every known path
    for (method, path, allow) in [
        ("DELETE", "/healthz", "GET"),
        ("POST", "/healthz", "GET"),
        ("DELETE", "/v1/stats", "GET"),
        ("GET", "/v1/generate", "POST"),
        ("GET", "/v1/embed", "POST"),
        ("POST", "/v1/collections", "GET"),
        ("GET", "/v1/collections/docs/add", "POST"),
        ("PUT", "/v1/collections/docs/query", "POST"),
    ] {
        let r = http_request(&addr, method, path, None).unwrap();
        assert_eq!(r.status, 405, "{method} {path}");
        assert_error_shape(&r);
        assert_eq!(
            header_of(&r, "allow"),
            Some(allow),
            "{method} {path} must name the allowed methods"
        );
    }

    // --- 400: malformed bodies on generate and every index POST
    for (path, body) in [
        ("/v1/generate", "{not json"),
        ("/v1/embed", "{}"),
        ("/v1/embed", r#"{"tokens":[999999]}"#),
        ("/v1/collections/docs/add", r#"{"vectors":[[1,2],[1,2,3]]}"#),
        ("/v1/collections/docs/query", r#"{"vector":[]}"#),
        ("/v1/collections/docs/query", r#"{"vector":[1],"k":0}"#),
    ] {
        let r = http_request(&addr, "POST", path, Some(body)).unwrap();
        assert_eq!(r.status, 400, "POST {path} {body}: {:?}", r.body_str());
        assert_error_shape(&r);
    }
    // bad collection name
    let r = http_request(
        &addr,
        "POST",
        "/v1/collections/bad%20name/add",
        Some(r#"{"vectors":[[1,2]]}"#),
    )
    .unwrap();
    assert_eq!(r.status, 400);
    assert_error_shape(&r);

    // --- 413: over-cap declared body
    {
        let mut conn = TcpStream::connect(&addr).unwrap();
        write!(
            conn,
            "POST /v1/embed HTTP/1.1\r\nHost: t\r\nContent-Length: 999999999\r\n\r\n"
        )
        .unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let resp = raana::net::read_response(&conn).unwrap();
        assert_eq!(resp.status, 413);
        assert_error_shape(&resp);
    }

    // --- 503 (overflow): pin the single connection worker with an
    // endless stream; generate AND the index POSTs must refuse with the
    // shape, while the cheap GETs stay live
    {
        let conn = TcpStream::connect(&addr).unwrap();
        let body = generate_body(&[2], 1_000_000, true);
        write!(
            &conn,
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        wait_generating(&server, 1);
        for (path, body) in [
            ("/v1/generate", r#"{"prompt":[1],"max_new_tokens":1}"#),
            ("/v1/embed", r#"{"text":"x"}"#),
            ("/v1/collections/docs/add", r#"{"texts":["x"]}"#),
            ("/v1/collections/docs/query", r#"{"text":"x"}"#),
        ] {
            let r = http_request(&addr, "POST", path, Some(body)).unwrap();
            assert_eq!(r.status, 503, "POST {path} under overflow");
            assert_error_shape(&r);
        }
        let r = http_request(&addr, "GET", "/v1/collections", None).unwrap();
        assert_eq!(r.status, 200, "collection accounting must survive a pinned pool");
        drop(conn);
    }
    // worker returns after the disconnect is noticed (poll)
    let mut ok = false;
    for _ in 0..600 {
        let r = http_request(
            &addr,
            "POST",
            "/v1/generate",
            Some(&generate_body(&[5], 1, false)),
        );
        if matches!(r, Ok(ref resp) if resp.status == 200) {
            ok = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(ok, "worker never came back after client disconnect");

    // --- 429: lane pinned in-process, queue filled, next submit refused
    {
        let pin = server.submit_streaming(vec![1], 1_000_000, 0.4, 2).unwrap();
        assert!(pin.events.recv_timeout(Duration::from_secs(30)).is_ok());
        let queued = server.submit(vec![2], 2, 0.0, 0).unwrap();
        let r = http_request(
            &addr,
            "POST",
            "/v1/generate",
            Some(&generate_body(&[3], 1, false)),
        )
        .unwrap();
        assert_eq!(r.status, 429, "full queue must answer 429: {:?}", r.body_str());
        assert_error_shape(&r);
        pin.cancel.cancel();
        let _ = queued.1.recv_timeout(Duration::from_secs(30));
    }

    shutdown_all(http, server);
}

#[test]
fn index_endpoints_answer_404_without_an_index() {
    let server = packed_server("http-noix", 8, 1, ServeConfig::default());
    let http = HttpServer::bind(Arc::clone(&server), "127.0.0.1:0", 2).unwrap();
    let addr = http.local_addr().to_string();
    for (method, path, body) in [
        ("POST", "/v1/embed", Some(r#"{"text":"x"}"#)),
        ("GET", "/v1/collections", None),
        ("POST", "/v1/collections/docs/add", Some(r#"{"texts":["x"]}"#)),
        ("POST", "/v1/collections/docs/query", Some(r#"{"text":"x"}"#)),
    ] {
        let r = http_request(&addr, method, path, body).unwrap();
        assert_eq!(r.status, 404, "{method} {path} without an index");
        let msg = assert_error_shape(&r);
        assert!(msg.contains("not enabled"), "got: {msg}");
    }
    // generation is untouched by the absence of an index
    let r = http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[1], 1, false)))
        .unwrap();
    assert_eq!(r.status, 200);
    shutdown_all(http, server);
}

#[test]
fn zero_max_new_tokens_over_http_is_empty_completion() {
    let server = packed_server("http-zero", 8, 1, ServeConfig::default());
    let http = HttpServer::bind(Arc::clone(&server), "127.0.0.1:0", 2).unwrap();
    let addr = http.local_addr().to_string();
    let r = http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[1], 0, false)))
        .unwrap();
    assert_eq!(r.status, 200);
    assert!(tokens_of(&r.json().unwrap()).is_empty());
    // streaming flavor: a single done chunk
    let r = http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[1], 0, true)))
        .unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.chunks.len(), 1);
    let v = json::parse(std::str::from_utf8(&r.chunks[0]).unwrap().trim_end()).unwrap();
    assert_eq!(v.get("done").unwrap().as_bool(), Some(true));
    shutdown_all(http, server);
}
