//! Loopback observability wall (ISSUE 10): real sockets, the packed
//! native demo model, a real 2-worker cluster — no mocks anywhere.
//!
//! The wall, in order:
//! (a) `GET /metrics` answers Prometheus text on a worker whose whole
//!     connection pool is pinned by an endless stream — scrapes must
//!     survive saturation exactly like `/healthz`;
//! (b) every response echoes `X-Request-Id`: a valid inbound id comes
//!     back verbatim on 200s AND on the error paths (400/404/405/413),
//!     a missing or malformed inbound id is replaced by a minted one;
//! (c) a request id sent to the ROUTER propagates through the
//!     router→worker relay and back: the client sees its own id, and
//!     the worker's batcher spans (queue_wait/prefill) plus the
//!     router's hop span all carry it — one id keys the whole tree;
//! (d) one streamed generate with a MID-STREAM DISCONNECT leaves a
//!     reconstructible span timeline in the JSONL sink: admission →
//!     queue_wait → prefill → N decode steps, grouped by rid, ordered
//!     by start_us;
//! (e) bit-determinism: greedy decode with tracing enabled produces
//!     exactly the tokens it produces with tracing disabled —
//!     instrumentation observes time, it never participates in compute;
//! (f) the router's fleet `/metrics` concatenates per-worker families
//!     under `worker="<i>"` labels with HELP/TYPE deduped;
//! (g) `/v1/stats` exposes the latency window in its summable form
//!     (bucket counts over shared edges) on workers, and the fleet
//!     stats' bucket counts equal the element-wise per-worker sum —
//!     the aggregation that is safe, unlike averaging percentiles.
//!
//! Tests that flip the PROCESS-WIDE tracer (enable, sink, ring asserts)
//! serialize on `TRACER_LOCK`; everything they assert on is filtered by
//! request id, so unrelated concurrent test traffic cannot interfere.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use raana::cluster::{Router, RouterConfig};
use raana::index::IndexConfig;
use raana::json::{self, Value};
use raana::model::synthetic_manifest;
use raana::net::{http_request, read_response, ClientConfig, HttpConfig, HttpServer};
use raana::obs::{self, trace, LATENCY_BUCKETS_US};
use raana::quant::{LayerCalib, TrickConfig};
use raana::runtime::{native_init, PackedLayers};
use raana::serve::index::IndexServer;
use raana::serve::{ServeConfig, Server};

/// Serializes tests that mutate global tracer state (enabled flag, JSONL
/// sink, ring clears). Request-id filtering makes the *assertions* safe
/// under concurrency; this lock makes the *state flips* safe.
static TRACER_LOCK: Mutex<()> = Mutex::new(());

// ------------------------------------------------------------- harness

fn packed_server(name: &str, eval_batch: usize, cfg: ServeConfig) -> Arc<Server> {
    let manifest = synthetic_manifest(name, 32, 1, 2, 64, 8, 256, eval_batch);
    let params = native_init(&manifest, 17);
    let stats: Vec<LayerCalib> =
        manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
    let bits = vec![4u8; manifest.linears.len()];
    let packed =
        PackedLayers::quantize(&manifest, &params, &bits, &stats, &TrickConfig::none(), 1, 1)
            .unwrap();
    Arc::new(Server::start_native_packed_with(manifest, params, packed, cfg).unwrap())
}

fn bind_uncapped(server: &Arc<Server>, workers: usize) -> HttpServer {
    HttpServer::bind_with(
        Arc::clone(server),
        "127.0.0.1:0",
        HttpConfig { workers, max_new_tokens_cap: usize::MAX, ..Default::default() },
    )
    .unwrap()
}

fn shutdown_all(http: HttpServer, server: Arc<Server>) {
    http.shutdown().unwrap();
    match Arc::try_unwrap(server) {
        Ok(s) => {
            s.shutdown().unwrap();
        }
        Err(_) => panic!("server still referenced after HTTP shutdown"),
    }
}

fn generate_body(prompt: &[i32], max_new_tokens: usize, stream: bool) -> String {
    format!(
        "{{\"prompt\":{prompt:?},\"max_new_tokens\":{max_new_tokens},\"temperature\":0,\
         \"seed\":0,\"stream\":{stream}}}"
    )
}

/// One raw request with an explicit `X-Request-Id` header (the stock
/// client attaches the *ambient* id; these tests need full control of
/// the inbound header, including sending garbage).
fn request_with_rid(
    addr: &str,
    method: &str,
    path: &str,
    rid: Option<&str>,
    body: Option<&str>,
) -> raana::net::HttpResponse {
    let conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = body.unwrap_or("");
    let rid_line = rid.map(|r| format!("X-Request-Id: {r}\r\n")).unwrap_or_default();
    write!(
        &conn,
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n{rid_line}\r\n{body}",
        body.len()
    )
    .unwrap();
    read_response(&conn).unwrap()
}

fn header_of<'a>(resp: &'a raana::net::HttpResponse, name: &str) -> Option<&'a str> {
    resp.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

fn rid_of(resp: &raana::net::HttpResponse) -> &str {
    header_of(resp, "x-request-id").expect("every response must carry X-Request-Id")
}

fn wait_generating(server: &Server, min_tokens: usize) {
    for _ in 0..6000 {
        if server.stats().tokens_generated >= min_tokens {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("server never started generating");
}

fn poll_until(what: &str, mut ok: impl FnMut() -> bool) {
    for _ in 0..600 {
        if ok() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

fn tokens_of(v: &Value) -> Vec<i32> {
    v.get("tokens")
        .and_then(|t| t.as_arr())
        .unwrap_or(&[])
        .iter()
        .filter_map(|x| x.as_f64())
        .map(|f| f as i32)
        .collect()
}

// --------------------------------------- (a) /metrics under saturation

#[test]
fn metrics_endpoint_stays_live_under_saturated_pool() {
    let server = packed_server("obs-live", 2, ServeConfig::default());
    let http = bind_uncapped(&server, 1); // ONE connection worker
    let addr = http.local_addr().to_string();

    // pin the only worker with an endless stream
    let conn = TcpStream::connect(&addr).unwrap();
    let body = generate_body(&[2], 1_000_000, true);
    write!(
        &conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    wait_generating(&server, 1);

    // generation is refused (the pool really is saturated)...
    let refused =
        http_request(&addr, "POST", "/v1/generate", Some(&generate_body(&[4], 2, false)))
            .unwrap();
    assert_eq!(refused.status, 503, "pinned pool must refuse generation");

    // ...but the scrape answers, in the exposition content type
    let scrape = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(scrape.status, 200, "/metrics must survive a pinned pool");
    assert_eq!(
        header_of(&scrape, "content-type"),
        Some("text/plain; version=0.0.4"),
        "scrapes must carry the exposition content type"
    );
    let text = scrape.body_str().unwrap().to_string();
    for family in [
        "# TYPE raana_http_requests_total counter",
        "raana_http_requests_total ",
        "raana_decode_step_us_bucket{le=\"+Inf\"}",
        "raana_decode_step_us_count ",
        "raana_tokens_generated_total ",
        "raana_lanes_active ",
        "raana_qgemm_calls_total ",
        "raana_dequant_calls_total ",
    ] {
        assert!(text.contains(family), "scrape missing {family:?}:\n{text}");
    }
    // the pinned stream has decoded tokens: the histogram must show them
    let count_line = text
        .lines()
        .find(|l| l.starts_with("raana_decode_step_us_count"))
        .expect("decode histogram count line");
    let count: u64 = count_line.rsplit(' ').next().unwrap().parse().unwrap();
    assert!(count > 0, "in-flight decode must land in the step histogram");

    drop(conn);
    poll_until("lane to free after disconnect", || server.stats().cancelled >= 1);
    shutdown_all(http, server);
}

// -------------------------------------------- (b) request-id echo wall

#[test]
fn request_ids_echo_on_success_and_every_error_path() {
    let server = packed_server("obs-rid", 1, ServeConfig::default());
    let http = HttpServer::bind(Arc::clone(&server), "127.0.0.1:0", 2).unwrap();
    let addr = http.local_addr().to_string();

    // a valid inbound id echoes verbatim on success
    let ok = request_with_rid(
        &addr,
        "POST",
        "/v1/generate",
        Some("obs-echo-ok.1"),
        Some(&generate_body(&[1, 2], 1, false)),
    );
    assert_eq!(ok.status, 200, "body: {:?}", ok.body_str());
    assert_eq!(rid_of(&ok), "obs-echo-ok.1");

    // ...and verbatim on every error shape the front-end can produce
    for (label, resp) in [
        ("400 bad json", request_with_rid(&addr, "POST", "/v1/generate", Some("obs-e400"), Some("{not json"))),
        ("404 route", request_with_rid(&addr, "GET", "/nope", Some("obs-e404"), None)),
        ("405 method", request_with_rid(&addr, "DELETE", "/v1/generate", Some("obs-e405"), None)),
    ] {
        let want = label.split(' ').next().unwrap().parse::<u16>().unwrap();
        assert_eq!(resp.status, want, "{label}: {:?}", resp.body_str());
        let inbound = match want {
            400 => "obs-e400",
            404 => "obs-e404",
            _ => "obs-e405",
        };
        assert_eq!(rid_of(&resp), inbound, "{label} must echo the inbound id");
    }

    // 413: the body is refused before it is read, the id still echoes
    {
        let conn = TcpStream::connect(&addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        write!(
            &conn,
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: 999999999\r\n\
             X-Request-Id: obs-e413\r\n\r\n"
        )
        .unwrap();
        let resp = read_response(&conn).unwrap();
        assert_eq!(resp.status, 413);
        assert_eq!(rid_of(&resp), "obs-e413");
    }

    // no inbound id: a minted one comes back (and passes the sanitizer)
    let minted = request_with_rid(&addr, "GET", "/healthz", None, None);
    assert_eq!(minted.status, 200);
    let m = rid_of(&minted);
    assert!(m.starts_with("r-"), "minted ids are r-<seq>-<us>, got {m}");
    assert!(trace::sanitize_rid(m).is_some(), "minted id must be header-safe");

    // malformed inbound id (space → header-unsafe): replaced, not echoed
    let replaced =
        request_with_rid(&addr, "GET", "/healthz", Some("bad id with spaces"), None);
    assert_eq!(replaced.status, 200);
    assert_ne!(rid_of(&replaced), "bad id with spaces");
    assert!(trace::sanitize_rid(rid_of(&replaced)).is_some());

    shutdown_all(http, server);
}

// -------------------------------------------- cluster harness (c)(f)(g)

struct WorkerNode {
    server: Arc<Server>,
    index: Arc<IndexServer>,
    http: HttpServer,
    addr: String,
}

impl WorkerNode {
    fn start() -> WorkerNode {
        let manifest = synthetic_manifest("obs-worker", 32, 1, 2, 64, 16, 256, 2);
        let params = native_init(&manifest, 17);
        let stats: Vec<LayerCalib> =
            manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
        let bits = vec![4u8; manifest.linears.len()];
        let packed =
            PackedLayers::quantize(&manifest, &params, &bits, &stats, &TrickConfig::none(), 1, 1)
                .unwrap();
        let index = Arc::new(
            IndexServer::with_embedder(
                IndexConfig::default(),
                None,
                manifest.clone(),
                params.clone(),
                Some(packed.clone()),
            )
            .unwrap(),
        );
        let server = Arc::new(
            Server::start_native_packed_with(manifest, params, packed, ServeConfig::default())
                .unwrap(),
        );
        let drain = Arc::new(AtomicBool::new(false));
        let http = HttpServer::bind_with_index(
            Arc::clone(&server),
            Some(Arc::clone(&index)),
            "127.0.0.1:0",
            HttpConfig { workers: 2, drain: Some(drain), ..Default::default() },
        )
        .unwrap();
        let addr = format!("127.0.0.1:{}", http.local_addr().port());
        WorkerNode { server, index, http, addr }
    }

    fn kill(self) {
        self.http.shutdown().unwrap();
        drop(self.index);
        match Arc::try_unwrap(self.server) {
            Ok(s) => {
                s.shutdown().unwrap();
            }
            Err(_) => panic!("server still referenced after HTTP shutdown"),
        }
    }
}

fn start_router(workers: Vec<String>) -> Router {
    Router::bind(
        "127.0.0.1:0",
        RouterConfig {
            workers,
            shards: 0,
            http_workers: 4,
            probe_interval_ms: 50,
            client: ClientConfig::timeout_ms(2000),
            ..Default::default()
        },
    )
    .unwrap()
}

fn raddr(router: &Router) -> String {
    format!("127.0.0.1:{}", router.local_addr().port())
}

// ------------------------------ (c) propagation router → worker → back

#[test]
fn request_id_propagates_router_to_worker_and_back() {
    let _guard = TRACER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let w0 = WorkerNode::start();
    let w1 = WorkerNode::start();
    let router = start_router(vec![w0.addr.clone(), w1.addr.clone()]);
    let ra = raddr(&router);

    let t = trace::tracer();
    t.clear();
    t.set_enabled(true);

    // the id crosses TWO hops: client → router (header), router → worker
    // (relayed header), worker → client (echo relayed verbatim)
    let rid = "obs-cluster-rid-1";
    let resp = request_with_rid(
        &ra,
        "POST",
        "/v1/generate",
        Some(rid),
        Some(&generate_body(&[10, 20, 30], 4, false)),
    );
    assert_eq!(resp.status, 200, "body: {:?}", resp.body_str());
    assert_eq!(
        rid_of(&resp),
        rid,
        "the worker's echoed id must come back through the relay"
    );
    assert_eq!(tokens_of(&resp.json().unwrap()).len(), 4);

    // workers and router share this process's tracer: the batcher spans
    // recorded while serving the relayed request must carry OUR id —
    // proof the id crossed the relay into the worker's admission path
    let spans = t.snapshot();
    let ours: Vec<&str> =
        spans.iter().filter(|s| &*s.rid == rid).map(|s| s.name).collect();
    for phase in ["admission", "queue_wait", "prefill", "router_hop"] {
        assert!(
            ours.contains(&phase),
            "span {phase:?} missing under rid {rid}: got {ours:?}"
        );
    }

    t.set_enabled(false);
    t.clear();
    router.shutdown().unwrap();
    w0.kill();
    w1.kill();
}

// ----------------------- (d) span tree from the JSONL sink, disconnect

#[test]
fn span_tree_reconstructs_from_jsonl_sink_after_midstream_disconnect() {
    let _guard = TRACER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let sink = std::env::temp_dir().join(format!("raana-obs-trace-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&sink);

    let server = packed_server("obs-sink", 1, ServeConfig::default());
    let http = bind_uncapped(&server, 2);
    let addr = http.local_addr().to_string();

    let t = trace::tracer();
    t.clear();
    t.set_jsonl_sink(&sink).unwrap();

    // one streamed generate, read a few chunks, then VANISH mid-stream
    let rid = "obs-span-tree-1";
    let prompt = [7i32, 8, 9];
    let mut conn = TcpStream::connect(&addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = generate_body(&prompt, 1_000_000, true);
    write!(
        &conn,
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
         X-Request-Id: {rid}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut some = [0u8; 256];
    conn.read_exact(&mut some).unwrap();
    // ≥5 tokens: the first comes from the prefill, so this guarantees at
    // least 4 decode rounds reached the sink before we vanish
    wait_generating(&server, 5);
    drop(conn);
    poll_until("disconnect to cancel the lane", || server.stats().cancelled >= 1);

    t.clear_jsonl_sink();
    t.set_enabled(false);
    t.clear();

    // every span is one self-contained JSON line, flushed at record time:
    // the tree must reconstruct from the file alone, disconnect and all
    let text = std::fs::read_to_string(&sink).unwrap();
    let mut ours: Vec<(String, u64, u64, i64)> = Vec::new();
    for line in text.lines() {
        let v = json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line:?}: {e}"));
        if v.get("rid").and_then(Value::as_str) == Some(rid) {
            ours.push((
                v.req_str("span").unwrap().to_string(),
                v.get("start_us").unwrap().as_f64().unwrap() as u64,
                v.get("dur_us").unwrap().as_f64().unwrap() as u64,
                v.get("note").unwrap().as_f64().unwrap() as i64,
            ));
        }
    }
    ours.sort_by_key(|s| s.1);
    let names: Vec<&str> = ours.iter().map(|s| s.0.as_str()).collect();

    // the timeline: admission, queue wait, prefill (note = prompt len),
    // then at least the decode steps we observed before disconnecting
    assert!(names.contains(&"admission"), "got {names:?}");
    let qw = ours.iter().position(|s| s.0 == "queue_wait").expect("queue_wait span");
    let pf = ours.iter().position(|s| s.0 == "prefill").expect("prefill span");
    assert!(qw < pf, "queue_wait must start before prefill: {names:?}");
    assert_eq!(ours[pf].3, prompt.len() as i64, "prefill note is the window length");
    let decodes: Vec<&(String, u64, u64, i64)> =
        ours.iter().filter(|s| s.0 == "decode").collect();
    assert!(decodes.len() >= 3, "expected >=3 decode spans, got {}", decodes.len());
    assert!(
        ours[pf].1 <= decodes[0].1,
        "prefill must start before the first decode step"
    );
    // decode notes are the generated-length counter: strictly increasing
    for pair in decodes.windows(2) {
        assert!(pair[0].3 < pair[1].3, "decode notes must increase: {decodes:?}");
    }

    shutdown_all(http, server);
    let _ = std::fs::remove_file(&sink);
}

// ---------------------------------------- (e) tracing bit-determinism

#[test]
fn greedy_decode_is_bit_identical_with_tracing_enabled() {
    let _guard = TRACER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let server = packed_server("obs-det", 1, ServeConfig::default());
    let prompt = vec![11i32, 22, 33];

    let t = trace::tracer();
    t.set_enabled(false);
    let (_, rx) = server.submit(prompt.clone(), 6, 0.0, 0).unwrap();
    let untraced = rx.recv().unwrap().tokens;

    t.clear();
    t.set_enabled(true);
    let (_, rx) = server.submit(prompt.clone(), 6, 0.0, 0).unwrap();
    let traced = rx.recv().unwrap().tokens;
    let recorded = t.snapshot().iter().filter(|s| s.name == "decode").count();
    t.set_enabled(false);
    t.clear();

    assert_eq!(
        traced, untraced,
        "tracing must never perturb generation — spans observe, they don't compute"
    );
    // 6 tokens = 1 from the prefill + 5 decode rounds
    assert!(recorded >= 5, "the traced run must actually have recorded decode spans");

    match Arc::try_unwrap(server) {
        Ok(s) => {
            s.shutdown().unwrap();
        }
        Err(_) => panic!("server still referenced"),
    }
}

// ------------------------------------------ (f) fleet /metrics labels

#[test]
fn fleet_metrics_concatenates_workers_with_labels_and_deduped_comments() {
    let w0 = WorkerNode::start();
    let w1 = WorkerNode::start();
    let router = start_router(vec![w0.addr.clone(), w1.addr.clone()]);
    let ra = raddr(&router);

    // some traffic so the counters are non-trivial on both sides
    for _ in 0..2 {
        let r = http_request(&ra, "POST", "/v1/generate", Some(&generate_body(&[9], 2, false)))
            .unwrap();
        assert_eq!(r.status, 200);
    }

    let scrape = http_request(&ra, "GET", "/metrics", None).unwrap();
    assert_eq!(scrape.status, 200);
    let text = scrape.body_str().unwrap().to_string();

    // NOTE: workers and the router share one process in this test, so
    // the numeric values overlap — the shape is what's under test: the
    // router's own unlabeled families plus one relabeled copy per worker
    for needle in [
        "\nraana_http_requests_total ",
        "raana_http_requests_total{worker=\"0\"} ",
        "raana_http_requests_total{worker=\"1\"} ",
        "raana_decode_step_us_bucket{worker=\"0\",le=\"+Inf\"}",
        "raana_decode_step_us_bucket{worker=\"1\",le=\"+Inf\"}",
        "raana_completions_total{worker=\"0\"}",
    ] {
        assert!(text.contains(needle), "fleet scrape missing {needle:?}");
    }
    // HELP/TYPE once per family across the whole concatenation
    let help_lines = text
        .lines()
        .filter(|l| l.starts_with("# HELP raana_http_requests_total"))
        .count();
    assert_eq!(help_lines, 1, "duplicate HELP lines must be suppressed");
    let type_lines =
        text.lines().filter(|l| l.starts_with("# TYPE raana_decode_step_us")).count();
    assert_eq!(type_lines, 1, "duplicate TYPE lines must be suppressed");

    router.shutdown().unwrap();
    w0.kill();
    w1.kill();
}

// ------------------------- (g) summable latency buckets, worker + fleet

#[test]
fn stats_expose_summable_latency_buckets_worker_and_fleet() {
    let w0 = WorkerNode::start();
    let w1 = WorkerNode::start();
    let router = start_router(vec![w0.addr.clone(), w1.addr.clone()]);
    let ra = raddr(&router);

    for _ in 0..6 {
        let r = http_request(&ra, "POST", "/v1/generate", Some(&generate_body(&[9], 2, false)))
            .unwrap();
        assert_eq!(r.status, 200);
    }

    let counts_of = |v: &Value, key: &str| -> Vec<u64> {
        v.get(key)
            .and_then(Value::as_arr)
            .unwrap_or_else(|| panic!("{key} missing"))
            .iter()
            .map(|c| c.as_f64().unwrap() as u64)
            .collect()
    };

    // worker side: edges are the shared ladder, counts cover the window
    let mut per_worker_counts: Vec<Vec<u64>> = Vec::new();
    let mut total_samples = 0u64;
    for w in [&w0, &w1] {
        let v = http_request(&w.addr, "GET", "/v1/stats", None).unwrap().json().unwrap();
        let edges = counts_of(&v, "latency_bucket_le_us");
        assert_eq!(edges, LATENCY_BUCKETS_US.to_vec(), "bucket edges must be the shared ladder");
        let counts = counts_of(&v, "latency_bucket_counts");
        assert_eq!(counts.len(), LATENCY_BUCKETS_US.len() + 1, "+Inf slot included");
        let window =
            v.get("latencies_secs").and_then(Value::as_arr).map(|a| a.len()).unwrap_or(0);
        assert_eq!(
            counts.iter().sum::<u64>(),
            window as u64,
            "every windowed completion lands in exactly one bucket"
        );
        total_samples += window as u64;
        per_worker_counts.push(counts);
    }
    assert_eq!(total_samples, 6, "all completions must be windowed somewhere");

    // fleet side: bucket counts equal the ELEMENT-WISE SUM of the
    // per-worker counts — the one latency aggregation that is exact
    // (percentiles, by contrast, are computed once over concatenation
    // and must never be combined; cluster.rs pins that half)
    let v = http_request(&ra, "GET", "/v1/stats", None).unwrap().json().unwrap();
    assert_eq!(
        counts_of(&v, "latency_bucket_le_us"),
        LATENCY_BUCKETS_US.to_vec(),
        "fleet edges must be the same shared ladder"
    );
    let fleet = counts_of(&v, "latency_bucket_counts");
    let want: Vec<u64> = (0..fleet.len())
        .map(|i| per_worker_counts.iter().map(|c| c[i]).sum())
        .collect();
    assert_eq!(fleet, want, "fleet buckets must be the element-wise worker sum");
    // and the per-worker passthrough is intact for dashboards
    let per = v.get("per_worker").and_then(Value::as_arr).unwrap();
    assert_eq!(per.len(), 2);
    for (w, entry) in per.iter().enumerate() {
        assert_eq!(
            counts_of(entry, "latency_buckets"),
            per_worker_counts[w],
            "worker {w} bucket passthrough drifted"
        );
    }

    // sanity on the registry constant the whole contract hangs off
    assert_eq!(obs::bucketize_us([0, 1, 2]).iter().sum::<u64>(), 3);

    router.shutdown().unwrap();
    w0.kill();
    w1.kill();
}
