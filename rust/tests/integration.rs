//! Integration tests over the real AOT artifacts (micro model).
//!
//! Requires `make artifacts` (MODELS includes `micro`). Every test shares
//! one PJRT client + compiled artifact set via a process-global lazy Env —
//! compiling the HLO once keeps the suite fast.

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, OnceLock};

use raana::calib::{calibrate, CalibMode};
use raana::data::{detokenize, tokenize, Corpus};
use raana::experiments::{
    baseline_quantize, raana_quantize, raana_quantize_with_calib, Baseline, Env,
};
use raana::model::{artifacts_root, ModelParams};
use raana::quant::TrickConfig;
use raana::runtime::{lit_f32, to_vec_f32, ModelRuntime, Runtime};
use raana::train::{train, TrainConfig};

fn artifacts_available() -> bool {
    artifacts_root().join("micro").join("manifest.json").exists()
}

/// PJRT handles are neither Send nor Sync, so each test builds its own Env
/// (micro artifacts compile in well under a second each). A global lock
/// serializes tests so the first one trains + writes the shared checkpoint
/// without races; later Envs just load it.
struct EnvGuard {
    _lock: MutexGuard<'static, ()>,
    env: Env,
}

impl std::ops::Deref for EnvGuard {
    type Target = Env;
    fn deref(&self) -> &Env {
        &self.env
    }
}

/// Global test lock: serializes tests that touch shared process state
/// (the training checkpoint, and the process-wide dequantization counter
/// asserted by `native_packed_serving_performs_zero_dequant`).
fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

fn env() -> EnvGuard {
    let lock = test_lock();
    std::env::set_var("RAANA_TRAIN_STEPS", "40");
    std::env::set_var("RAANA_TRAIN_SEQS", "400");
    std::env::set_var("RAANA_TEST_SEQS", "16");
    EnvGuard {
        _lock: lock,
        env: Env::load("micro").expect("run `make artifacts` first"),
    }
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts/micro missing (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn init_params_match_manifest_shapes() {
    require_artifacts!();
    let e = env();
    let p = e.mrt.init(123).unwrap();
    assert_eq!(p.specs.len(), e.mrt.manifest.params.len());
    for (spec, t) in p.specs.iter().zip(&p.tensors) {
        assert_eq!(spec.numel(), t.len(), "{}", spec.name);
    }
    // embeddings should be non-trivial, biases zero
    let emb = p.get("tok_emb").unwrap();
    assert!(emb.iter().any(|&x| x != 0.0));
    let b = p.get("blk0.attn.wq.b").unwrap();
    assert!(b.iter().all(|&x| x == 0.0));
}

#[test]
fn init_is_seed_deterministic() {
    require_artifacts!();
    let e = env();
    let a = e.mrt.init(5).unwrap();
    let b = e.mrt.init(5).unwrap();
    let c = e.mrt.init(6).unwrap();
    assert_eq!(a.tensors, b.tensors);
    assert_ne!(a.tensors, c.tensors);
}

#[test]
fn training_reduces_loss() {
    require_artifacts!();
    let e = env();
    let mut params = e.mrt.init(9).unwrap();
    let cfg = TrainConfig { steps: 25, log_every: 5, ..Default::default() };
    let logs = train(&e.mrt, &mut params, &e.wiki, &cfg).unwrap();
    assert!(logs.len() >= 2);
    let first = logs.first().unwrap().loss;
    let last = logs.last().unwrap().loss;
    assert!(
        last < first - 0.3,
        "training should reduce loss: {first} -> {last}"
    );
}

#[test]
fn env_checkpoint_roundtrips_through_disk() {
    require_artifacts!();
    let e = env();
    let reloaded = ModelParams::load(&e.ckpt_path).unwrap();
    assert_eq!(reloaded.tensors, e.params.tensors);
}

#[test]
fn perplexity_sane_and_deterministic() {
    require_artifacts!();
    let e = env();
    let p1 = e.perplexity(&e.params, &e.wiki, 8).unwrap();
    let p2 = e.perplexity(&e.params, &e.wiki, 8).unwrap();
    assert_eq!(p1, p2);
    // trained 40 steps on bytes: far better than uniform (256), worse than 1.5
    assert!(p1 > 1.5 && p1 < 200.0, "ppl {p1}");
}

#[test]
fn calibration_produces_positive_stable_alphas() {
    require_artifacts!();
    let e = env();
    let few = calibrate(&e.mrt, &e.params, &CalibMode::FewShot(3), &e.wiki).unwrap();
    let zero = calibrate(&e.mrt, &e.params, &CalibMode::ZeroShot, &e.wiki).unwrap();
    let nl = e.mrt.manifest.linears.len();
    assert_eq!(few.alphas.len(), nl);
    assert_eq!(zero.alphas.len(), nl);
    assert!(few.alphas.iter().all(|&a| a > 0.0 && a.is_finite()));
    assert!(zero.alphas.iter().all(|&a| a > 0.0 && a.is_finite()));
    // zero-shot alphas should correlate with few-shot (paper section 4.2):
    // same argsort on at least the top layer
    let top_few = few
        .alphas
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let rank_zero = {
        let mut idx: Vec<usize> = (0..nl).collect();
        idx.sort_by(|&a, &b| zero.alphas[b].partial_cmp(&zero.alphas[a]).unwrap());
        idx.iter().position(|&i| i == top_few).unwrap()
    };
    assert!(rank_zero < nl / 2, "few-shot top layer ranked {rank_zero} by zero-shot");
}

#[test]
fn calibration_hessians_are_gram_matrices() {
    require_artifacts!();
    let e = env();
    let c = calibrate(&e.mrt, &e.params, &CalibMode::FewShot(2), &e.wiki).unwrap();
    for (h, lin) in c.hessians.iter().zip(&e.mrt.manifest.linears) {
        assert_eq!((h.rows, h.cols), (lin.d, lin.d));
        // symmetric PSD-ish: diagonal non-negative, h[i][j] == h[j][i]
        for i in 0..lin.d.min(8) {
            assert!(h.at(i, i) >= 0.0);
            for j in 0..i {
                assert!((h.at(i, j) - h.at(j, i)).abs() < 1e-2);
            }
        }
    }
}

#[test]
fn raana_ppl_improves_with_bits_and_stays_close_at_4() {
    require_artifacts!();
    let e = env();
    let ppl_fp = e.perplexity(&e.params, &e.wiki, 8).unwrap();
    let calib = calibrate(&e.mrt, &e.params, &CalibMode::FewShot(5), &e.wiki).unwrap();
    let mut ppls = Vec::new();
    for target in [2.1f64, 3.1, 4.1] {
        let (qp, report) = raana_quantize_with_calib(
            &e, &calib, target, &(1..=8).collect::<Vec<u8>>(),
            &TrickConfig::default(), 7, 0,
        )
        .unwrap();
        // honest accounting: actual avg bits within 0.5 of target
        assert!(
            (report.avg_bits - target).abs() < 0.5,
            "target {target} actual {}",
            report.avg_bits
        );
        ppls.push(e.perplexity(&qp, &e.wiki, 8).unwrap());
    }
    assert!(ppls[2] <= ppls[0] * 1.05, "4-bit should beat 2-bit: {ppls:?}");
    assert!(
        ppls[2] < ppl_fp * 1.10,
        "4-bit RaanA within 10% of fp32: {} vs {ppl_fp}",
        ppls[2]
    );
}

#[test]
fn zero_shot_calibration_works_end_to_end() {
    require_artifacts!();
    let e = env();
    let (qp, report) = raana_quantize(
        &e, &CalibMode::ZeroShot, 4.1, &(1..=8).collect::<Vec<u8>>(),
        &TrickConfig::default(), 7, 0,
    )
    .unwrap();
    let ppl_fp = e.perplexity(&e.params, &e.wiki, 8).unwrap();
    let ppl_q = e.perplexity(&qp, &e.wiki, 8).unwrap();
    assert!(
        ppl_q < ppl_fp * 1.15,
        "zero-shot 4-bit ppl {ppl_q} vs fp {ppl_fp}"
    );
    assert!(report.avg_bits < 5.5);
}

#[test]
fn baselines_run_and_rank_sanely() {
    require_artifacts!();
    let e = env();
    let calib = calibrate(&e.mrt, &e.params, &CalibMode::FewShot(5), &e.wiki).unwrap();
    let ppl_fp = e.perplexity(&e.params, &e.wiki, 8).unwrap();
    for method in [Baseline::Rtn, Baseline::Gptq, Baseline::Awq, Baseline::EasyQuant] {
        let (qp, avg) = baseline_quantize(&e, &calib, method, 4).unwrap();
        let ppl = e.perplexity(&qp, &e.wiki, 8).unwrap();
        assert!(
            ppl < ppl_fp * 1.25,
            "{} 4-bit ppl {ppl} vs fp {ppl_fp}",
            method.name()
        );
        // micro layers are 64-256 dims, so per-group/outlier side payloads
        // dominate (realistic layers land near the paper's +0.25)
        assert!(avg >= 4.0 && avg < 5.5, "{} avg {avg}", method.name());
    }
}

#[test]
fn fwd_logits_agree_with_fwd_loss_distribution() {
    require_artifacts!();
    let e = env();
    let m = &e.mrt.manifest;
    // build a batch whose next token is highly predictable: repeated text
    let text = "abcabcabc".repeat(40);
    let toks = tokenize(&text);
    let mut batch = Vec::new();
    for _ in 0..m.eval_batch {
        batch.extend_from_slice(&toks[..m.seq_len]);
    }
    let logits = e.mrt.last_logits(&e.params, &batch).unwrap();
    assert_eq!(logits.len(), m.eval_batch * m.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn qmatmul_artifact_matches_rust_estimator() {
    require_artifacts!();
    let _e = env(); // ensure artifacts tree exists
    let path = artifacts_root()
        .join("kernels")
        .join("qmatmul_128x256x256_b4.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: kernel artifacts missing");
        return;
    }
    use raana::rabitq::{QuantizedMatrix, ScaleMode};
    use raana::rng::Rng;
    use raana::tensor::Matrix;
    let rt = Runtime::cpu().unwrap();
    let art = rt.load(&path).unwrap();
    let (n, d, c, bits) = (128usize, 256usize, 256usize, 4u8);
    let v = Matrix::from_vec(d, c, Rng::new(1).gaussian_vec(d * c));
    let x = Matrix::from_vec(n, d, Rng::new(2).gaussian_vec(n * d));
    let qm = QuantizedMatrix::quantize(&v, bits, ScaleMode::MaxAbs, 2);
    let want = qm.matmul_est(&x);
    let unpacked = qm.codes.unpack();
    let mut codes_f32 = vec![0f32; d * c];
    for j in 0..c {
        for i in 0..d {
            codes_f32[i * c + j] = unpacked[j * d + i] as f32;
        }
    }
    let outs = art
        .run(&[
            lit_f32(&x.data, &[n, d]).unwrap(),
            lit_f32(&codes_f32, &[d, c]).unwrap(),
            lit_f32(&qm.r, &[c]).unwrap(),
        ])
        .unwrap();
    let got = Matrix::from_vec(n, c, to_vec_f32(&outs[0]).unwrap());
    assert!(got.rel_err(&want) < 1e-4, "rel err {}", got.rel_err(&want));
}

#[test]
fn hadamard_artifact_matches_rust_rht() {
    require_artifacts!();
    let _e = env();
    let path = artifacts_root().join("kernels").join("hadamard_128x256.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: kernel artifacts missing");
        return;
    }
    use raana::hadamard::rht;
    use raana::rng::Rng;
    let rt = Runtime::cpu().unwrap();
    let art = rt.load(&path).unwrap();
    let (n, d) = (128usize, 256usize);
    let mut rng = Rng::new(3);
    let x = rng.gaussian_vec(n * d);
    let signs = rng.rademacher_vec(d);
    let outs = art
        .run(&[
            lit_f32(&x, &[n, d]).unwrap(),
            lit_f32(&signs, &[d]).unwrap(),
        ])
        .unwrap();
    let got = to_vec_f32(&outs[0]).unwrap();
    // Rust applies the same transform row by row
    let mut want = x;
    for row in want.chunks_mut(d) {
        rht(row, &signs);
    }
    let err: f64 = got
        .iter()
        .zip(&want)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let norm: f64 = want.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
    assert!(err / norm < 1e-4, "rel err {}", err / norm);
}

#[test]
fn server_round_trip_over_quantized_weights() {
    require_artifacts!();
    let qparams = {
        let e = env();
        let (qp, _) = raana_quantize(
            &e, &CalibMode::FewShot(3), 4.1, &(1..=8).collect::<Vec<u8>>(),
            &TrickConfig::default(), 7, 0,
        )
        .unwrap();
        qp
    }; // env lock released before the server spawns its own runtime

    let server = raana::serve::Server::start(
        move || {
            let rt = Runtime::cpu()?;
            ModelRuntime::load(&rt, &artifacts_root(), "micro")
        },
        qparams,
    );
    let mut rxs = Vec::new();
    for i in 0..5 {
        let (_, rx) = server.submit(tokenize("the fox "), 6, 0.0, i).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 6);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
        let _ = detokenize(&c.tokens);
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.completions, 5);
    assert!(stats.tokens_generated >= 30);
    assert!(stats.batch_steps >= 6, "greedy same-prompt batch: >= 6 steps");
}

#[test]
fn quantized_checkpoint_roundtrip_preserves_ppl() {
    require_artifacts!();
    let e = env();
    let (qp, _) = raana_quantize(
        &e, &CalibMode::FewShot(2), 3.1, &(1..=8).collect::<Vec<u8>>(),
        &TrickConfig::default(), 7, 0,
    )
    .unwrap();
    let dir: PathBuf = std::env::temp_dir().join(format!("raana_it_{}", std::process::id()));
    let path = dir.join("q.rkpt");
    qp.save(&path).unwrap();
    let qp2 = ModelParams::load(&path).unwrap();
    let a = e.perplexity(&qp, &e.wiki, 4).unwrap();
    let b = e.perplexity(&qp2, &e.wiki, 4).unwrap();
    assert_eq!(a, b);
    let _ = std::fs::remove_dir_all(&dir);
}

/// ISSUE 1 acceptance criterion: the serve path performs **zero**
/// full-matrix dequantizations per forward. Runs without artifacts — the
/// native backend + synthetic manifest stand in for the PJRT stack.
#[test]
fn native_packed_serving_performs_zero_dequant() {
    use raana::model::synthetic_manifest;
    use raana::quant::LayerCalib;
    use raana::runtime::{native_init, PackedLayers};

    let _lock = test_lock(); // exclusive: the dequant counter is global

    let manifest = synthetic_manifest("zero-dequant", 32, 2, 2, 64, 16, 256, 2);
    let params = native_init(&manifest, 9);
    let mrt_probe = raana::runtime::ModelRuntime::native(manifest.clone()).unwrap();
    // calibration stats from a native capture forward (tricks active)
    let calib_tokens: Vec<i32> = (0..2 * 16).map(|i| (i * 11 % 256) as i32).collect();
    let stats: Vec<LayerCalib> = mrt_probe
        .native_model
        .capture_layer_stats(&manifest, &params, &calib_tokens, 2)
        .unwrap();
    let bits = vec![4u8; manifest.linears.len()];
    let packed = PackedLayers::quantize(
        &manifest,
        &params,
        &bits,
        &stats,
        &TrickConfig::default(),
        7,
        2,
    )
    .unwrap();

    let mut mrt = raana::runtime::ModelRuntime::native(manifest).unwrap();
    mrt.attach_packed(packed).unwrap();

    let tokens: Vec<i32> = (0..2 * 16).map(|i| (i * 3 % 256) as i32).collect();
    let before = raana::rabitq::dequant_calls();
    for step in 0..4 {
        let logits = mrt.last_logits(&params, &tokens).unwrap();
        assert_eq!(logits.len(), 2 * 256, "step {step}");
        assert!(logits.iter().all(|x| x.is_finite()));
    }
    let nll = mrt.token_nll(&params, &tokens).unwrap();
    assert!(nll.iter().all(|x| x.is_finite()));

    // The KV-cached request path is held to the same bar: prefill, batched
    // decode steps, and a window-slide re-prefill must all run on packed
    // codes with zero full-matrix dequantization.
    let mut cache = mrt.new_kv_cache(2);
    mrt.prefill(&params, &mut cache, 0, &tokens[..5]).unwrap();
    mrt.prefill(&params, &mut cache, 1, &tokens[..9]).unwrap();
    for step in 0..6 {
        let logits = mrt
            .decode_step(&params, &mut cache, &[0, 1], &[(step * 7) % 256, (step * 11) % 256])
            .unwrap();
        assert_eq!(logits.len(), 2 * 256, "decode step {step}");
        assert!(logits.iter().all(|x| x.is_finite()));
    }
    // slide slot 1 to a fresh full window (the wraparound path)
    let window: Vec<i32> = (0..16).map(|i| (i * 5 % 256) as i32).collect();
    mrt.prefill(&params, &mut cache, 1, &window).unwrap();

    // The quantized KV cache is held to the same bar: storing rows as
    // codes and attending over them must never dequantize either.
    let plan = raana::kvq::KvqPlan::uniform(2, 4).unwrap();
    let mut qcache = mrt
        .new_kv_cache_quantized(1, plan, raana::kvq::DEFAULT_ROT_SEED)
        .unwrap();
    mrt.prefill(&params, &mut qcache, 0, &tokens[..6]).unwrap();
    for step in 0..4 {
        mrt.decode_step(&params, &mut qcache, &[0], &[(step * 13) % 256]).unwrap();
    }
    assert_eq!(
        raana::rabitq::dequant_calls(),
        before,
        "forwards over packed weights must not dequantize (incl. prefill/decode \
         and the quantized KV cache)"
    );
}

/// ISSUE 5 acceptance criterion: the vector index's packed-code scan
/// dequantizes **zero** full rows outside the rerank — counter-enforced
/// by the same mechanism as the zero-dequant forward test above. The
/// rerank-read counter must move by exactly `rerank_factor * k` per
/// query (the candidate set, nothing more), and the full-matrix
/// dequantization counter must stay flat through adds and queries.
#[test]
fn index_scan_reads_zero_rows_outside_rerank() {
    use raana::index::{rerank_row_reads, IndexConfig, IndexPolicy, VectorStore};
    use raana::rng::Rng;

    let _lock = test_lock(); // exclusive: both counters are process-global

    let (n, d, k, rf) = (256usize, 64usize, 4usize, 4usize);
    let mut store = VectorStore::new(IndexConfig {
        policy: IndexPolicy::Uniform(8),
        ..Default::default()
    })
    .unwrap();
    let dequant_before = raana::rabitq::dequant_calls();
    store.add("zero", &Rng::new(5).gaussian_vec(n * d), d, 1).unwrap();

    for (seed, threads) in [(10u64, 1usize), (11, 4), (12, 2)] {
        let q = Rng::new(seed).gaussian_vec(d);
        let reads_before = rerank_row_reads();
        let hits = store.query("zero", &q, k, rf, threads).unwrap();
        assert_eq!(hits.len(), k);
        assert_eq!(
            rerank_row_reads() - reads_before,
            rf * k,
            "a query over {n} rows must fetch exactly its {rf}x{k} rerank \
             candidates from the residual store — the scan itself reads codes only"
        );
    }
    // phase 1 alone (rerank_factor 1): exactly k fetches
    let reads_before = rerank_row_reads();
    store.query("zero", &Rng::new(13).gaussian_vec(d), k, 1, 1).unwrap();
    assert_eq!(rerank_row_reads() - reads_before, k);

    assert_eq!(
        raana::rabitq::dequant_calls(),
        dequant_before,
        "index adds and queries must never full-matrix dequantize"
    );
}

/// ISSUE 2 acceptance criterion: KV-cached incremental decoding is
/// **bit-identical** to the full-recompute forward — for random models
/// (dense and packed weights), random prompt lengths, mixed batch
/// occupancies, and across the window slide at max context.
#[test]
fn kv_decode_bit_exact_vs_recompute_property() {
    use raana::model::synthetic_manifest;
    use raana::quant::LayerCalib;
    use raana::runtime::{native_init, ModelRuntime, PackedLayers};

    // (d_model, n_layers, n_heads, d_ff, seq_len, vocab); d=48 exercises
    // both practical-RHT windows inside the packed linears
    let shapes = [(32usize, 2usize, 2usize, 64usize, 12usize, 256usize),
                  (48, 1, 4, 96, 10, 128)];
    for (cfg, &(d, layers, heads, dff, seq, vocab)) in shapes.iter().enumerate() {
        let manifest =
            synthetic_manifest(&format!("kv-prop-{cfg}"), d, layers, heads, dff, seq, vocab, 2);
        let params = native_init(&manifest, 100 + cfg as u64);

        // calibration stats from a capture forward so the packed layers
        // exercise outliers + centralization, and mixed bit-widths
        let probe = ModelRuntime::native(manifest.clone()).unwrap();
        let calib_tokens: Vec<i32> =
            (0..2 * seq).map(|i| ((i * 17 + cfg) % vocab) as i32).collect();
        let stats: Vec<LayerCalib> = probe
            .native_model
            .capture_layer_stats(&manifest, &params, &calib_tokens, 2)
            .unwrap();
        let bits: Vec<u8> =
            (0..manifest.linears.len()).map(|k| [3u8, 5, 8][k % 3]).collect();
        let packed = PackedLayers::quantize(
            &manifest, &params, &bits, &stats, &TrickConfig::default(), 7, 2,
        )
        .unwrap();

        // two runtimes: dense weights and packed codes — both must hold
        let dense_mrt = ModelRuntime::native(manifest.clone()).unwrap();
        let mut packed_mrt = ModelRuntime::native(manifest).unwrap();
        packed_mrt.attach_packed(packed).unwrap();

        for (which, mrt) in [("dense", &dense_mrt), ("packed", &packed_mrt)] {
            let mut cache = mrt.new_kv_cache(3);
            // three lanes at different prompt lengths (1, mid, full window)
            let mut ctxs: Vec<Vec<i32>> = vec![
                vec![((7 + cfg) % vocab) as i32],
                (0..seq / 2).map(|i| ((i * 13 + 1) % vocab) as i32).collect(),
                (0..seq).map(|i| ((i * 29 + 2) % vocab) as i32).collect(),
            ];
            let mut last: Vec<Vec<f32>> = Vec::new();
            for (slot, ctx) in ctxs.iter().enumerate() {
                let logits = mrt.prefill(&params, &mut cache, slot, ctx).unwrap();
                let want = mrt.last_logits_ctx(&params, ctx).unwrap();
                assert_eq!(logits, want, "{which} cfg {cfg} slot {slot}: prefill");
                last.push(logits);
            }
            // generate past max context so every lane eventually slides
            for step in 0..seq {
                // greedy next token per lane, from the incremental logits
                let next: Vec<i32> =
                    last.iter().map(|l| raana::util::argmax(l) as i32).collect();
                // batched decode over in-window lanes; full lanes slide
                let decode: Vec<usize> =
                    (0..3).filter(|&s| !cache.is_full(s)).collect();
                let toks: Vec<i32> = decode.iter().map(|&s| next[s]).collect();
                if !decode.is_empty() {
                    let rows = mrt
                        .decode_step(&params, &mut cache, &decode, &toks)
                        .unwrap();
                    for (i, &s) in decode.iter().enumerate() {
                        last[s] = rows[i * vocab..(i + 1) * vocab].to_vec();
                    }
                }
                for s in 0..3 {
                    ctxs[s].push(next[s]);
                    if !decode.contains(&s) {
                        // wraparound: slide the window by re-prefilling
                        let window = &ctxs[s][ctxs[s].len() - seq..];
                        last[s] = mrt.prefill(&params, &mut cache, s, window).unwrap();
                    }
                    // reference: full recompute of the truncated context
                    let lo = ctxs[s].len().saturating_sub(seq);
                    let want = mrt.last_logits_ctx(&params, &ctxs[s][lo..]).unwrap();
                    assert_eq!(
                        last[s], want,
                        "{which} cfg {cfg} slot {s} step {step}: KV logits \
                         must be bit-identical to recompute"
                    );
                }
            }
        }
    }
}

/// ISSUE 4 acceptance criterion: quantized-KV serving is **bounded
/// drift**, not bit-exact. Teacher-forced along the f32 cache's greedy
/// trajectory (so every step compares identical contexts), the 8-bit
/// quantized cache must agree with the f32 cache's greedy choice on
/// >= 75% of steps (threshold documented in EXPERIMENTS.md §KV
/// compression), and the per-step logit drift must fall strictly as the
/// bit-width climbs 2 -> 4 -> 8 — across dense AND packed weights, and
/// across the window slide.
#[test]
fn quantized_kv_greedy_agreement_and_quality_ladder() {
    use raana::kvq::{KvqPlan, DEFAULT_ROT_SEED};
    use raana::model::synthetic_manifest;
    use raana::quant::LayerCalib;
    use raana::runtime::{native_init, ModelRuntime, PackedLayers};

    let manifest = synthetic_manifest("kvq-accept", 32, 2, 2, 64, 12, 256, 1);
    let params = native_init(&manifest, 31);
    let stats: Vec<LayerCalib> =
        manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
    let bits = vec![6u8; manifest.linears.len()];
    let packed = PackedLayers::quantize(
        &manifest, &params, &bits, &stats, &TrickConfig::none(), 3, 2,
    )
    .unwrap();
    let dense_mrt = ModelRuntime::native(manifest.clone()).unwrap();
    let mut packed_mrt = ModelRuntime::native(manifest.clone()).unwrap();
    packed_mrt.attach_packed(packed).unwrap();

    let seq = manifest.seq_len;
    let gen_len = 2 * seq; // crosses the window slide twice
    let prompt: Vec<i32> = vec![3, 1, 4, 1, 5];

    /// Teacher-forced pass: walk `forced` (or greedy when None) through
    /// `cache`, returning the per-step logits rows.
    fn drive(
        mrt: &raana::runtime::ModelRuntime,
        params: &raana::model::ModelParams,
        mut cache: raana::runtime::KvCache,
        prompt: &[i32],
        gen_len: usize,
        seq: usize,
        forced: Option<&[i32]>,
    ) -> Vec<Vec<f32>> {
        let mut ctx = prompt.to_vec();
        let mut logits = mrt.prefill(params, &mut cache, 0, &ctx).unwrap();
        let mut rows = vec![logits.clone()];
        for step in 0..gen_len {
            let tok = match forced {
                Some(toks) => toks[step],
                None => raana::util::argmax(&logits) as i32,
            };
            ctx.push(tok);
            logits = if cache.is_full(0) {
                let window = &ctx[ctx.len() - seq..];
                mrt.prefill(params, &mut cache, 0, window).unwrap()
            } else {
                mrt.decode_step(params, &mut cache, &[0], &[tok]).unwrap()
            };
            rows.push(logits.clone());
        }
        rows
    }

    for (which, mrt) in [("dense", &dense_mrt), ("packed", &packed_mrt)] {
        // f32-cache reference trajectory (greedy)
        let ref_rows =
            drive(mrt, &params, mrt.new_kv_cache(1), &prompt, gen_len, seq, None);
        let ref_toks: Vec<i32> =
            ref_rows[..gen_len].iter().map(|r| raana::util::argmax(r) as i32).collect();

        let mut prev_drift = f64::INFINITY;
        let mut agreement8 = 0.0;
        for kv_bits in [2u8, 4, 8] {
            let plan = KvqPlan::uniform(manifest.n_layers, kv_bits).unwrap();
            let cache = mrt.new_kv_cache_quantized(1, plan, DEFAULT_ROT_SEED).unwrap();
            // teacher-forced along the reference trajectory: every step
            // compares logits over the *identical* token context
            let q_rows =
                drive(mrt, &params, cache, &prompt, gen_len, seq, Some(&ref_toks));
            let mut drift = 0f64;
            let mut agree = 0usize;
            for (qr, rr) in q_rows.iter().zip(&ref_rows) {
                let num: f64 = qr
                    .iter()
                    .zip(rr)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let den: f64 =
                    rr.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
                drift += num / den;
                if raana::util::argmax(qr) == raana::util::argmax(rr) {
                    agree += 1;
                }
            }
            drift /= q_rows.len() as f64;
            let agreement = agree as f64 / q_rows.len() as f64;
            assert!(
                drift < prev_drift,
                "{which} kv_bits={kv_bits}: logit drift {drift} !< {prev_drift} \
                 (2->4->8 ladder must be monotone)"
            );
            assert!(drift.is_finite());
            prev_drift = drift;
            if kv_bits == 8 {
                agreement8 = agreement;
            }
        }
        assert!(prev_drift < 0.05, "{which}: 8-bit mean logit drift {prev_drift}");
        assert!(
            agreement8 >= 0.75,
            "{which}: 8-bit greedy agreement {agreement8} below the 0.75 threshold \
             (EXPERIMENTS.md §KV compression)"
        );
    }
}

/// End-to-end batching server over the native packed runtime — the
/// request path exercised without any AOT artifacts.
#[test]
fn native_packed_server_round_trip() {
    use raana::model::synthetic_manifest;
    use raana::quant::LayerCalib;
    use raana::runtime::{native_init, ModelRuntime, PackedLayers};

    let manifest = synthetic_manifest("native-serve", 32, 2, 2, 64, 16, 256, 2);
    let params = native_init(&manifest, 21);
    let stats: Vec<LayerCalib> =
        manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
    let bits = vec![5u8; manifest.linears.len()];
    let packed = PackedLayers::quantize(
        &manifest,
        &params,
        &bits,
        &stats,
        &TrickConfig::none(),
        13,
        2,
    )
    .unwrap();

    let m2 = manifest.clone();
    let server = raana::serve::Server::start(
        move || {
            let mut mrt = ModelRuntime::native(m2)?;
            mrt.attach_packed(packed)?;
            Ok(mrt)
        },
        params,
    );
    let mut rxs = Vec::new();
    for i in 0..4 {
        let (_, rx) = server.submit(tokenize("the fox "), 5, 0.0, i).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let c = rx.recv().unwrap();
        assert_eq!(c.tokens.len(), 5);
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.completions, 4);
    assert!(stats.tokens_generated >= 20);
}

/// ISSUE 7 acceptance criterion (determinism wall, kernel level): every
/// parallel kernel produces bit-identical output across pool widths
/// 1/2/3/7/8 and across repeated calls on a warm pool. All calls run on
/// the process-wide persistent worker pool ([`raana::threadpool::global`]),
/// so the repeats also prove no state leaks between jobs.
#[test]
fn parallel_kernels_bit_identical_across_pool_widths() {
    use raana::hadamard::{fwht_batch, PracticalRht};
    use raana::kernels::{gemm, qgemm, scan_scores_f32, scan_scores_q};
    use raana::rabitq::{quantize_column, PackedCodes, QuantizedMatrix, ScaleMode};
    use raana::rng::Rng;
    use raana::tensor::Matrix;

    const WIDTHS: [usize; 5] = [1, 2, 3, 7, 8];
    const WARM_REPEATS: usize = 3;
    let mut rng = Rng::new(0x700);

    // qgemm over packed codes at several bit widths (the 1/4-bit widths
    // take the autovectorized bulk decoder, 3/7 the streaming path)
    let (n, d, c) = (9usize, 48usize, 33usize);
    let x = Matrix::from_vec(n, d, rng.gaussian_vec(n * d));
    for bits in [1u8, 3, 4, 7] {
        let w = Matrix::from_vec(d, c, rng.gaussian_vec(d * c));
        let qm = QuantizedMatrix::quantize(&w, bits, ScaleMode::MaxAbs, 1);
        let want = qgemm(&x, &qm, 1);
        for &t in &WIDTHS {
            for rep in 0..WARM_REPEATS {
                let got = qgemm(&x, &qm, t);
                assert_eq!(
                    got.data, want.data,
                    "qgemm bits={bits} threads={t} rep={rep}"
                );
            }
        }
    }

    // scan_scores_q over a packed row store (n > ROW_BLOCK so the scan
    // actually splits across workers), plus the f32 scan
    let (sn, sd, sbits) = (300usize, 40usize, 5u8);
    let mut all_codes = Vec::with_capacity(sn * sd);
    let mut r = Vec::with_capacity(sn);
    for _ in 0..sn {
        let (codes, rr) = quantize_column(&rng.gaussian_vec(sd), sbits, ScaleMode::MaxAbs);
        all_codes.extend_from_slice(&codes);
        r.push(rr);
    }
    let packed = PackedCodes::pack(&all_codes, sbits);
    let q = rng.gaussian_vec(sd);
    let mut want_q = vec![0f32; sn];
    scan_scores_q(&q, &packed.data, sbits, 0, sn, &r, 1, &mut want_q);
    let rows_f32: Vec<f32> = rng.gaussian_vec(sn * sd);
    let mut want_f = vec![0f32; sn];
    scan_scores_f32(&q, &rows_f32, sn, 1, &mut want_f);
    for &t in &WIDTHS {
        for rep in 0..WARM_REPEATS {
            let mut got = vec![0f32; sn];
            scan_scores_q(&q, &packed.data, sbits, 0, sn, &r, t, &mut got);
            assert_eq!(got, want_q, "scan_scores_q threads={t} rep={rep}");
            let mut got_f = vec![0f32; sn];
            scan_scores_f32(&q, &rows_f32, sn, t, &mut got_f);
            assert_eq!(got_f, want_f, "scan_scores_f32 threads={t} rep={rep}");
        }
    }

    // fwht_batch, PracticalRht::apply_rows (d=48: two overlapping
    // Hadamard windows), and the dense gemm
    let base = rng.gaussian_vec(64 * 32);
    let mut want_h = base.clone();
    fwht_batch(&mut want_h, 32, 1);
    let rot = PracticalRht::sample(48, &mut rng);
    let m0 = Matrix::from_vec(37, 48, rng.gaussian_vec(37 * 48));
    let mut want_rot = m0.clone();
    rot.apply_rows_threaded(&mut want_rot, 1);
    let (gm, gk, gn) = (17usize, 23usize, 29usize);
    let a = rng.gaussian_vec(gm * gk);
    let b = rng.gaussian_vec(gk * gn);
    let mut want_g = vec![0f32; gm * gn];
    gemm(gm, gk, gn, &a, &b, &mut want_g, 1);
    for &t in &WIDTHS {
        for rep in 0..WARM_REPEATS {
            let mut got_h = base.clone();
            fwht_batch(&mut got_h, 32, t);
            assert_eq!(got_h, want_h, "fwht_batch threads={t} rep={rep}");
            let mut got_rot = m0.clone();
            rot.apply_rows_threaded(&mut got_rot, t);
            assert_eq!(got_rot.data, want_rot.data, "apply_rows threads={t} rep={rep}");
            let mut got_g = vec![0f32; gm * gn];
            gemm(gm, gk, gn, &a, &b, &mut got_g, t);
            assert_eq!(got_g, want_g, "gemm threads={t} rep={rep}");
        }
    }
}

/// ISSUE 7 acceptance criterion (determinism wall, end to end): greedy
/// decode through the native model is bit-identical across pool widths
/// 1/2/3/7/8 — dense weights, packed codes (the qgemm path), and the
/// quantized KV cache (the attend_cached_q path), covering prefill and
/// the KV-cached decode step at every width.
#[test]
fn greedy_decode_bit_identical_across_pool_widths() {
    use raana::kvq::{KvqPlan, DEFAULT_ROT_SEED};
    use raana::model::synthetic_manifest;
    use raana::quant::LayerCalib;
    use raana::runtime::{native_init, KvCache, NativeModel, PackedLayers};

    const WIDTHS: [usize; 5] = [1, 2, 3, 7, 8];
    let manifest = synthetic_manifest("pool-width", 32, 2, 2, 64, 12, 256, 2);
    let params = native_init(&manifest, 77);
    let nm = NativeModel::new(&manifest).unwrap();
    let stats: Vec<LayerCalib> =
        manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
    let bits: Vec<u8> =
        (0..manifest.linears.len()).map(|k| [4u8, 6, 8][k % 3]).collect();
    let packed = PackedLayers::quantize(
        &manifest, &params, &bits, &stats, &TrickConfig::default(), 7, 2,
    )
    .unwrap();

    let prompt: Vec<i32> = (0..7).map(|i| (i * 31 % 256) as i32).collect();
    let gen = 4usize; // 7 + 4 = 11 < seq_len 12: stays inside the window

    let modes: [(&str, Option<&PackedLayers>, bool); 3] = [
        ("dense", None, false),
        ("packed", Some(&packed), false),
        ("packed+kvq", Some(&packed), true),
    ];
    for (mode, packed_opt, kvq) in modes {
        // the width-1 (serial) trajectory is the reference the parallel
        // widths must reproduce bit for bit
        let mut reference: Option<Vec<Vec<f32>>> = None;
        for &t in &WIDTHS {
            let mut cache = if kvq {
                let plan = KvqPlan::uniform(manifest.n_layers, 8).unwrap();
                nm.new_kv_cache_quantized(1, plan, DEFAULT_ROT_SEED).unwrap()
            } else {
                KvCache::new(manifest.n_layers, 1, manifest.seq_len, manifest.d_model)
            };
            let mut rows = Vec::new();
            let mut logits = nm
                .prefill(&manifest, &params, packed_opt, &prompt, &mut cache, 0, t)
                .unwrap();
            rows.push(logits.clone());
            for _ in 0..gen {
                let tok = raana::util::argmax(&logits) as i32;
                logits = nm
                    .decode_step(&manifest, &params, packed_opt, &mut cache, &[0], &[tok], t)
                    .unwrap();
                rows.push(logits.clone());
            }
            match &reference {
                None => reference = Some(rows),
                Some(want) => assert_eq!(
                    &rows, want,
                    "{mode} threads={t}: greedy decode must be bit-identical \
                     across pool widths"
                ),
            }
        }
    }
}

/// ISSUE 7 acceptance criterion: after `NativeModel` construction, a
/// full-sequence forward plus prefill + N decode steps performs **zero**
/// name-based parameter/linear lookups — counter-enforced exactly like
/// the zero-dequant wall above, across dense and packed weights.
#[test]
fn native_serving_performs_zero_name_resolutions() {
    use raana::model::synthetic_manifest;
    use raana::quant::LayerCalib;
    use raana::runtime::{native_init, ModelRuntime, PackedLayers};

    let _lock = test_lock(); // exclusive: the resolution counter is global

    let manifest = synthetic_manifest("zero-resolve", 32, 2, 2, 64, 16, 256, 2);
    let params = native_init(&manifest, 41);
    let stats: Vec<LayerCalib> =
        manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
    let bits = vec![4u8; manifest.linears.len()];
    let packed = PackedLayers::quantize(
        &manifest, &params, &bits, &stats, &TrickConfig::none(), 7, 2,
    )
    .unwrap();

    let dense_mrt = ModelRuntime::native(manifest.clone()).unwrap();
    let mut packed_mrt = ModelRuntime::native(manifest).unwrap();
    packed_mrt.attach_packed(packed).unwrap();

    let tokens: Vec<i32> = (0..2 * 16).map(|i| (i * 3 % 256) as i32).collect();
    // every one-time resolution (manifest walks, `format!`-ed block names)
    // happened during construction above; from here the counter is flat
    let before = raana::model::name_resolutions();
    for mrt in [&dense_mrt, &packed_mrt] {
        let logits = mrt.last_logits(&params, &tokens).unwrap();
        assert!(logits.iter().all(|x| x.is_finite()));
        let nll = mrt.token_nll(&params, &tokens).unwrap();
        assert!(nll.iter().all(|x| x.is_finite()));
        let mut cache = mrt.new_kv_cache(2);
        mrt.prefill(&params, &mut cache, 0, &tokens[..5]).unwrap();
        mrt.prefill(&params, &mut cache, 1, &tokens[..9]).unwrap();
        for step in 0..6 {
            mrt.decode_step(
                &params,
                &mut cache,
                &[0, 1],
                &[(step * 7) % 256, (step * 11) % 256],
            )
            .unwrap();
        }
    }
    assert_eq!(
        raana::model::name_resolutions(),
        before,
        "steady-state serving must perform zero name-based parameter/linear \
         lookups — they are all precomputed at NativeModel construction"
    );
}

#[test]
fn corpus_respects_model_seq_len() {
    require_artifacts!();
    let e = env();
    assert_eq!(e.wiki.seq_len, e.mrt.manifest.seq_len);
    assert!(e.wiki.n_test >= 8);
    let c = Corpus::from_text("x", 4, 0.5);
    assert!(e.perplexity(&e.params, &c, 4).is_err(), "seq_len mismatch must error");
}
