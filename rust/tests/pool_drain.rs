//! Global-pool shutdown during serve drain — isolated in its own
//! integration binary (its own process) on purpose: shutting down the
//! process-wide worker pool is permanent, and after it every parallel
//! helper degrades to the caller-inline path. Keeping this wall out of
//! the shared test binaries means the determinism suites elsewhere keep
//! their real multi-worker parallelism.
//!
//! This file intentionally holds exactly one `#[test]`: a second test in
//! the same binary would race the irreversible shutdown.

use raana::model::synthetic_manifest;
use raana::quant::{LayerCalib, TrickConfig};
use raana::runtime::{native_init, ModelRuntime, PackedLayers};

/// ISSUE 7 lifecycle wall: shutting down the global pool while the serve
/// batcher is mid-drain must neither hang nor drop completions. The pool
/// guarantees this structurally — submitters always participate in their
/// own jobs, so a shut-down pool degrades to inline execution instead of
/// deadlocking — and the bits coming out are unchanged.
#[test]
fn global_pool_shutdown_during_serve_drain_completes() {
    let manifest = synthetic_manifest("pool-drain", 32, 2, 2, 64, 16, 256, 2);
    let params = native_init(&manifest, 17);
    let stats: Vec<LayerCalib> =
        manifest.linears.iter().map(|l| LayerCalib::zeros(l.d)).collect();
    let bits = vec![4u8; manifest.linears.len()];
    let packed = PackedLayers::quantize(
        &manifest, &params, &bits, &stats, &TrickConfig::none(), 7, 2,
    )
    .unwrap();

    // Warm the pool before the server starts, so the shutdown below races
    // an actually-spawned worker set, not a lazily never-started one.
    let warm: Vec<usize> = (0..64).collect();
    let doubled = raana::threadpool::parallel_map(&warm, 4, |_, &v| v * 2);
    assert_eq!(doubled[63], 126);

    let m2 = manifest.clone();
    let server = raana::serve::Server::start(
        move || {
            let mut mrt = ModelRuntime::native(m2)?;
            mrt.attach_packed(packed)?;
            Ok(mrt)
        },
        params,
    );
    let mut rxs = Vec::new();
    for i in 0..6 {
        let (_, rx) = server
            .submit(raana::data::tokenize("the fox "), 5, 0.0, i)
            .unwrap();
        rxs.push(rx);
    }
    // Kill the pool while the batcher is draining the queue.
    raana::threadpool::global().shutdown();
    for (i, rx) in rxs.into_iter().enumerate() {
        let c = rx.recv().expect("completion must arrive after pool shutdown");
        assert_eq!(c.tokens.len(), 5, "request {i}");
        assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)));
    }
    let stats = server.shutdown().unwrap();
    assert_eq!(stats.completions, 6);
    assert!(stats.tokens_generated >= 30);

    // The helpers stay serviceable inline after shutdown, and still
    // produce the same bits they did with live workers.
    let after = raana::threadpool::parallel_map(&warm, 8, |_, &v| v * 2);
    assert_eq!(after, doubled);
}
