//! Segment + compaction wall: crash safety of the seal→compact→swap
//! lifecycle, and the structural properties the segmented layout exists
//! for (seal cost proportional to the mutable head, not the store).
//!
//! The crash wall extends `rust/tests/durability.rs` to compaction:
//! every fault kind at every write ordinal across a run that seals
//! several small segments and then compacts them into one. Failed
//! writes must lose nothing; torn/bit-flipped writes (the
//! strictly-worse model — StdIo's temp+fsync+rename cannot tear) must
//! leave recovery equal to a fresh build of SOME exact acknowledged
//! prefix, never a hybrid. The workload runs under the Uniform policy,
//! so compaction is purely physical (merge files, swap the manifest)
//! and the full-workload fresh build is the reference at every
//! ordinal past the last ack.
//!
//! A fixture wall replays `rust/tests/vectors/segments.json` (authored
//! by `python/tests/gen_vectors.py`, mirrored by
//! `python/tests/test_segments.py`), pinning the segment + manifest
//! wire formats — including the stale-width requantize path — across
//! languages.

use std::path::{Path, PathBuf};

use raana::index::durability::{DurabilityConfig, DurableStore, FsyncPolicy};
use raana::index::io::{Fault, FaultIo, Io, MemIo};
use raana::index::snapshot::encode_snapshot;
use raana::index::{IndexConfig, IndexPolicy, Metric, VectorStore};
use raana::json::{self, Value};
use raana::rng::Rng;

const DATA_DIR: &str = "/idx";

fn cfg() -> IndexConfig {
    IndexConfig { policy: IndexPolicy::Uniform(6), ..Default::default() }
}

fn dcfg(snapshot_every: usize) -> DurabilityConfig {
    DurabilityConfig {
        data_dir: PathBuf::from(DATA_DIR),
        fsync: FsyncPolicy::Always,
        snapshot_every,
        segment_rows: 0,
    }
}

#[derive(Clone, Copy)]
struct AddSpec {
    seed: u64,
    rows: usize,
    d: usize,
}

fn vectors_of(spec: &AddSpec) -> Vec<f32> {
    Rng::new(spec.seed).gaussian_vec(spec.rows * spec.d)
}

fn fresh_prefix(adds: &[AddSpec], prefix: usize) -> VectorStore {
    let mut store = VectorStore::new(cfg()).unwrap();
    for spec in &adds[..prefix] {
        store.add("docs", &vectors_of(spec), spec.d, 1).unwrap();
    }
    store
}

/// Four 1-row adds with `snapshot_every = 1` — each add seals its own
/// segment (append + segment + manifest = 3 writes), then one
/// compaction merges all four (merged segment + manifest = 2 writes):
/// 14 writes in a clean run.
fn compaction_workload() -> Vec<AddSpec> {
    (0..4u64).map(|i| AddSpec { seed: 900 + i, rows: 1, d: 16 }).collect()
}

/// Run the workload + a compaction pass through `fault`, crash, and
/// recover from whatever survived. Add and compaction errors are
/// tolerated — the driver models a process that limps on and crashes
/// later.
fn crash_and_recover_compacting(adds: &[AddSpec], fault: Fault) -> DurableStore {
    let io = FaultIo::new(MemIo::new(), fault);
    let durable = DurableStore::open_with(cfg(), dcfg(1), Box::new(io)).unwrap();
    for spec in adds {
        let _ = durable.add("docs", &vectors_of(spec), spec.d, 1);
    }
    let _ = durable.compact_now(1);
    let io = durable.into_io().unwrap();
    DurableStore::open_with(cfg(), dcfg(1), io).unwrap()
}

fn assert_some_exact_prefix(recovered: &DurableStore, adds: &[AddSpec], what: &str) -> usize {
    let got = encode_snapshot(&recovered.store(), 0);
    for k in (0..=adds.len()).rev() {
        if got == encode_snapshot(&fresh_prefix(adds, k), 0) {
            return k;
        }
    }
    panic!("{what}: recovered state matches no exact prefix of the workload");
}

#[test]
fn clean_seal_compact_swap_recovers_bit_for_bit() {
    let adds = compaction_workload();
    let recovered = crash_and_recover_compacting(&adds, Fault::FailWrite { nth: 10_000 });
    assert_eq!(
        encode_snapshot(&recovered.store(), 0),
        encode_snapshot(&fresh_prefix(&adds, adds.len()), 0),
        "recovery after a compacted run must equal the fresh build bit-for-bit"
    );
    // and the physical layout really was compacted: one merged segment
    let s = recovered.store();
    assert_eq!(s.segments(), 1, "four 1-row segments merged into one");
    assert_eq!(s.head_rows(), 0);
}

#[test]
fn failed_write_at_every_ordinal_through_compaction_loses_nothing() {
    // 14 writes in the clean run (see compaction_workload): wherever
    // one FailWrite lands — an append (resealed on the spot), a cadence
    // seal (non-fatal, WAL kept, retried), or either compaction write
    // (the pass errors out; the pre-compaction generation stands) —
    // recovery equals the full fresh build and drops nothing.
    let adds = compaction_workload();
    for nth in 1..=14 {
        let recovered = crash_and_recover_compacting(&adds, Fault::FailWrite { nth });
        assert_eq!(
            encode_snapshot(&recovered.store(), 0),
            encode_snapshot(&fresh_prefix(&adds, adds.len()), 0),
            "FailWrite nth={nth}: nothing acked may be lost"
        );
        let rep = recovered.recovery().unwrap();
        assert_eq!(rep.dropped_records, 0, "FailWrite nth={nth}");
    }
}

#[test]
fn torn_or_flipped_write_at_every_ordinal_recovers_an_exact_prefix() {
    // the strictly-worse model across the whole lifecycle, including
    // both compaction writes: a mangled manifest is pruned immediately
    // (fallback to the kept predecessor); a mangled segment file fails
    // its generation's CRC at recovery (fallback likewise). Whatever
    // the ordinal, the recovered state is a fresh build of some exact
    // acknowledged prefix.
    let adds = compaction_workload();
    for nth in 1..=14 {
        for fault in [
            Fault::TornWrite { nth, keep: 11 },
            Fault::FlipBit { nth, byte: 14, bit: 6 },
        ] {
            let what = format!("compaction run {fault:?}");
            let recovered = crash_and_recover_compacting(&adds, fault);
            assert_some_exact_prefix(&recovered, &adds, &what);
        }
    }
}

#[test]
fn torn_merged_segment_falls_back_to_the_uncompacted_generation() {
    // pin the most interesting single case from the sweep: the
    // compaction's merged-segment write (ordinal 13) lands torn, the
    // swap manifest (ordinal 14) commits and references it. Recovery
    // must reject the compacted generation on the segment CRC and fall
    // back to the kept pre-compaction generation — which still
    // references all four small segments, so NOTHING is lost.
    let adds = compaction_workload();
    let recovered =
        crash_and_recover_compacting(&adds, Fault::TornWrite { nth: 13, keep: 20 });
    assert_eq!(
        encode_snapshot(&recovered.store(), 0),
        encode_snapshot(&fresh_prefix(&adds, adds.len()), 0),
        "fallback across a torn compaction must keep every row"
    );
    let rep = recovered.recovery().unwrap();
    assert_eq!(rep.corrupt_snapshots, 1, "the compacted generation must fail its CRC");
    let s = recovered.store();
    assert_eq!(s.segments(), 4, "recovered from the four-segment predecessor");
}

#[test]
fn seal_cost_scales_with_the_head_not_the_store() {
    // the headline O(head) property, asserted structurally: eight
    // cadence seals as the store grows 8x write segment files of
    // IDENTICAL size, because each seal serializes only its head rows.
    // (The monolithic snapshot this replaces rewrote the whole store
    // every time — its encoding of the final state is several times
    // larger than any one segment.)
    let adds: Vec<AddSpec> =
        (0..8u64).map(|i| AddSpec { seed: 300 + i, rows: 4, d: 16 }).collect();
    let durable = DurableStore::open_with(cfg(), dcfg(4), Box::new(MemIo::new())).unwrap();
    for spec in &adds {
        durable.add("docs", &vectors_of(spec), spec.d, 1).unwrap();
    }
    let whole_store = encode_snapshot(&durable.store(), 0).len();
    let mut io = durable.into_io().unwrap();
    let seg_dir = Path::new(DATA_DIR).join("segments");
    let files = io.list(&seg_dir).unwrap();
    assert_eq!(files.len(), 8, "one segment per cadence seal");
    let sizes: Vec<usize> = files
        .iter()
        .map(|f| io.read(&seg_dir.join(f)).unwrap().unwrap().len())
        .collect();
    assert!(
        sizes.iter().all(|&s| s == sizes[0]),
        "every seal wrote the same few head rows, store size notwithstanding: {sizes:?}"
    );
    assert!(
        whole_store > 4 * sizes[0],
        "a monolithic snapshot ({whole_store} B) dwarfs one sealed head ({} B)",
        sizes[0]
    );
}

#[test]
fn recovered_compacted_store_serves_queries() {
    // end-to-end sanity on the recovered physical layout: scatter-gather
    // across the merged segment + replayed head must find a stored row
    let adds = compaction_workload();
    let recovered = crash_and_recover_compacting(&adds, Fault::FailWrite { nth: 10_000 });
    // one more add lands in the (empty) head so the query spans both
    let extra = AddSpec { seed: 990, rows: 1, d: 16 };
    recovered.add("docs", &vectors_of(&extra), extra.d, 1).unwrap();
    let q = vectors_of(&adds[2]);
    let hits = recovered.query("docs", &q, 1, 4, 1).unwrap();
    assert_eq!(hits[0].id, 2, "a sealed row must retrieve itself after recovery");
    let q2 = vectors_of(&extra);
    let hits2 = recovered.query("docs", &q2, 1, 4, 1).unwrap();
    assert_eq!(hits2[0].id, 4, "a head row must retrieve itself alongside sealed segments");
}

// ------------------------------------------------- cross-language fixtures

fn load_fixture() -> Value {
    let path: PathBuf = [env!("CARGO_MANIFEST_DIR"), "rust", "tests", "vectors", "segments.json"]
        .iter()
        .collect();
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "{} unreadable ({e}) — regenerate with python/tests/gen_vectors.py",
            path.display()
        )
    });
    json::parse(&text).expect("segments fixture must be valid JSON")
}

fn unhex(s: &str) -> Vec<u8> {
    assert!(s.len() % 2 == 0, "hex string length must be even");
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).expect("valid hex"))
        .collect()
}

fn fixture_cfg(case: &Value) -> IndexConfig {
    let bits = case.req_usize("bits").unwrap() as u8;
    let metric = match case.req_str("metric").unwrap() {
        "ip" => Metric::InnerProduct,
        "cosine" => Metric::Cosine,
        m => panic!("unknown metric '{m}' in fixture"),
    };
    IndexConfig { policy: IndexPolicy::Uniform(bits), metric, ..Default::default() }
}

#[test]
fn recovery_matches_python_segment_fixtures() {
    let doc = load_fixture();
    let cases = doc.req("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 4, "expected the segment-format edge cases at least");
    for case in cases {
        let name = case.req_str("name").unwrap().to_string();
        let mut io = MemIo::new();
        let Value::Obj(files) = case.req("files").unwrap() else {
            panic!("case '{name}': 'files' must be an object")
        };
        for (file, hex) in files {
            io.put(&Path::new(DATA_DIR).join(file), unhex(hex.as_str().unwrap()));
        }
        let store = DurableStore::open_with(fixture_cfg(case), dcfg(0), Box::new(io))
            .unwrap_or_else(|e| panic!("case '{name}': recovery failed: {e}"));
        let rep = store.recovery().unwrap();
        let expect = case.req("expect").unwrap();
        let want = |k: &str| expect.req_usize(k).unwrap();
        assert_eq!(rep.snapshot_rows, want("snapshot_rows"), "case '{name}': snapshot_rows");
        assert_eq!(rep.replayed_rows, want("replayed_rows"), "case '{name}': replayed_rows");
        assert_eq!(
            rep.dropped_records,
            want("dropped_records"),
            "case '{name}': dropped_records"
        );
        assert_eq!(
            rep.corrupt_snapshots,
            want("corrupt_snapshots"),
            "case '{name}': corrupt_snapshots"
        );
        assert_eq!(store.next_seq(), want("next_seq") as u64, "case '{name}': next_seq");
        assert_eq!(store.store().rows(), want("rows"), "case '{name}': rows");
        assert_eq!(store.store().segments(), want("segments"), "case '{name}': segments");
        // the decisive check: the canonical re-encoding must match the
        // bytes Python computed independently — including requantized
        // codes when the manifest's width differs from the file's
        let want_snap = unhex(expect.req_str("reencoded_snapshot").unwrap());
        let got_snap = encode_snapshot(&store.store(), store.next_seq());
        assert_eq!(
            got_snap, want_snap,
            "case '{name}': canonical re-encoding diverged from the Python mirror"
        );
    }
}
