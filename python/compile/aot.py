"""AOT lowering: JAX entry points -> HLO **text** artifacts + manifest.

Run once at build time (`make artifacts`); Python never appears on the Rust
request path.  HLO text (NOT `lowered.compile()`/`.serialize()`) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly.

Layout:
    artifacts/<model>/{init_params,train_step,fwd_loss,fwd_logits,
                       calib_grads,calib_capture}.hlo.txt
    artifacts/<model>/manifest.json     — shapes/orders the Rust side wires
    artifacts/kernels/qmatmul_*.hlo.txt — standalone Alg.-3 kernel
    artifacts/kernels/hadamard_*.hlo.txt
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.hadamard import rht_pallas
from .kernels.qmatmul import qmatmul_pallas


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) // 1024} KiB)")


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_model(cfg: M.ModelConfig, outdir: str):
    print(f"[aot] model '{cfg.name}' -> {outdir}")
    specs = M.param_specs(cfg)
    pspecs = [_spec(s) for _, s in specs]
    tok_train = _spec((cfg.train_batch, cfg.seq_len), jnp.int32)
    tok_eval = _spec((cfg.eval_batch, cfg.seq_len), jnp.int32)
    tok_calib = _spec((cfg.calib_batch, cfg.seq_len), jnp.int32)

    # init_params(seed) -> params
    lowered = jax.jit(lambda seed: tuple(M.init_params(cfg, seed))).lower(
        _spec((), jnp.int32))
    _write(f"{outdir}/init_params.hlo.txt", to_hlo_text(lowered))

    # train_step(params.., m.., v.., step, lr, tokens) -> (params.., m.., v.., loss)
    def _train(*args):
        n = len(pspecs)
        p, m, v = args[:n], args[n:2 * n], args[2 * n:3 * n]
        step, lr, tokens = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        np_, nm, nv, loss = M.train_step(cfg, p, m, v, step, lr, tokens)
        return np_ + nm + nv + (loss,)

    lowered = jax.jit(_train).lower(
        *pspecs, *pspecs, *pspecs, _spec((), jnp.int32), _spec(()),
        tok_train)
    _write(f"{outdir}/train_step.hlo.txt", to_hlo_text(lowered))

    # fwd_loss(params.., tokens) -> per-token nll (B, S-1)
    lowered = jax.jit(
        lambda *a: (M.fwd_loss(cfg, a[:-1], a[-1]),)
    ).lower(*pspecs, tok_eval)
    _write(f"{outdir}/fwd_loss.hlo.txt", to_hlo_text(lowered))

    # fwd_logits(params.., tokens) -> last-position logits (B, V)
    lowered = jax.jit(
        lambda *a: (M.fwd_logits(cfg, a[:-1], a[-1]),)
    ).lower(*pspecs, tok_eval)
    _write(f"{outdir}/fwd_logits.hlo.txt", to_hlo_text(lowered))

    # calib_grads(params.., tokens) -> (gnorms (L,), xnorms (L,))
    lowered = jax.jit(
        lambda *a: M.calib_grads(cfg, a[:-1], a[-1])
    ).lower(*pspecs, tok_calib)
    _write(f"{outdir}/calib_grads.hlo.txt", to_hlo_text(lowered))

    # calib_capture(params.., tokens) -> per-layer X_k
    lowered = jax.jit(
        lambda *a: M.calib_capture(cfg, a[:-1], a[-1])
    ).lower(*pspecs, tok_calib)
    _write(f"{outdir}/calib_capture.hlo.txt", to_hlo_text(lowered))

    manifest = {
        "model": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
            "train_batch": cfg.train_batch, "eval_batch": cfg.eval_batch,
            "calib_batch": cfg.calib_batch,
        },
        "params": [{"name": n, "shape": list(s)} for n, s in specs],
        "linears": M.linear_registry(cfg),
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS,
                 "wd": M.ADAM_WD},
        "artifacts": {
            "init_params": {"inputs": ["seed:i32"], "outputs": ["params"]},
            "train_step": {"inputs": ["params", "m", "v", "step:i32",
                                      "lr:f32", "tokens:train"],
                           "outputs": ["params", "m", "v", "loss:f32"]},
            "fwd_loss": {"inputs": ["params", "tokens:eval"],
                         "outputs": ["nll:(B,S-1)"]},
            "fwd_logits": {"inputs": ["params", "tokens:eval"],
                           "outputs": ["last_logits:(B,V)"]},
            "calib_grads": {"inputs": ["params", "tokens:calib"],
                            "outputs": ["gnorms:(L,)", "xnorms:(L,)"]},
            "calib_capture": {"inputs": ["params", "tokens:calib"],
                              "outputs": ["x_k per linear"]},
        },
    }
    _write(f"{outdir}/manifest.json", json.dumps(manifest, indent=1))


# Kernel artifact shapes: (n, d, c, bits) for qmatmul, (n, d) for hadamard.
QMATMUL_SHAPES = [
    (128, 256, 256, 2), (128, 256, 256, 3), (128, 256, 256, 4),
    (128, 1024, 256, 4), (128, 512, 512, 4),
]
HADAMARD_SHAPES = [(128, 256), (128, 512), (128, 1024), (128, 4096)]


def lower_kernels(outdir: str):
    print(f"[aot] kernels -> {outdir}")
    for n, d, c, bits in QMATMUL_SHAPES:
        fn = functools.partial(qmatmul_pallas, bits=bits)
        lowered = jax.jit(lambda x, cd, r: (fn(x, cd, r),)).lower(
            _spec((n, d)), _spec((d, c)), _spec((c,)))
        _write(f"{outdir}/qmatmul_{n}x{d}x{c}_b{bits}.hlo.txt",
               to_hlo_text(lowered))
    for n, d in HADAMARD_SHAPES:
        lowered = jax.jit(lambda x, s: (rht_pallas(x, s),)).lower(
            _spec((n, d)), _spec((d,)))
        _write(f"{outdir}/hadamard_{n}x{d}.hlo.txt", to_hlo_text(lowered))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output root")
    ap.add_argument("--models", default="tiny",
                    help="comma-separated model configs (tiny,small,micro)")
    ap.add_argument("--skip-kernels", action="store_true")
    args = ap.parse_args()

    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        cfg = M.CONFIGS[name]
        lower_model(cfg, os.path.join(args.out, name))
    if not args.skip_kernels:
        lower_kernels(os.path.join(args.out, "kernels"))
    print("[aot] done")


if __name__ == "__main__":
    main()
