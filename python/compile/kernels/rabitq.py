"""L1 Pallas kernel: RaBitQ grid quantization of RHT-rotated weight columns.

Per column v of the rotated weight block (paper Alg. 2 inner step):
  t      = max|v| / c_b                      (grid scale)
  codes  = clip(round(v / t + c_b), 0, 2^b-1)
  r      = <v, q> / <q, q>,  q = codes - c_b (least-squares rescale)

so that v ~= r * (codes - c_b) and Algorithm 3's estimator is the
least-squares-optimal collinear reconstruction.  The Rust hot path
(rust/src/rabitq/) implements the same procedure plus an optional scale
*search*; this kernel is the max-abs (search-free) variant and both are
cross-checked against kernels.ref.ref_rabitq_quantize.

Grid: one step per column block; the whole d-row column strip lives in
VMEM (d <= 4096 -> d * bc * 4 bytes <= 2 MiB for bc = 128).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rabitq_kernel(v_ref, codes_ref, r_ref, *, bits):
    v = v_ref[...]
    cb = (2.0**bits - 1.0) / 2.0
    maxabs = jnp.max(jnp.abs(v), axis=0)
    t = jnp.where(maxabs > 0, maxabs / cb, 1.0)
    codes = jnp.clip(jnp.round(v / t[None, :] + cb), 0.0, 2.0**bits - 1.0)
    q = codes - cb
    num = jnp.sum(v * q, axis=0)
    den = jnp.sum(q * q, axis=0)
    codes_ref[...] = codes.astype(codes_ref.dtype)
    r_ref[...] = jnp.where(den > 0, num / den, 0.0).astype(r_ref.dtype)


def _pick_block(n, pref=128):
    b = 1
    while b * 2 <= min(n, pref) and n % (b * 2) == 0:
        b *= 2
    return b


def rabitq_quantize_pallas(v, *, bits, bc=128):
    """Quantize columns of v (d, c) to `bits`-bit codes plus rescales r."""
    d, c = v.shape
    bc = _pick_block(c, bc)
    grid = (c // bc,)
    return pl.pallas_call(
        functools.partial(_rabitq_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((d, bc), lambda j: (0, j))],
        out_specs=[
            pl.BlockSpec((d, bc), lambda j: (0, j)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, c), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ],
        interpret=True,
    )(v)


@functools.partial(jax.jit, static_argnames=("bits",))
def rabitq_quantize_jit(v, bits):
    return rabitq_quantize_pallas(v, bits=bits)
