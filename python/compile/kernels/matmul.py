"""L1 Pallas kernel: MXU-tiled blocked matmul.

Used by the L2 model (python/compile/model.py) for every registered linear
layer, wrapped in a custom_vjp so training/calibration gradients flow
through a plain-jnp backward while the forward lowers to this kernel.

TPU mapping: each grid step holds an (bm, K) x (K, bn) tile pair in VMEM
and feeds the MXU with a single dot; the grid expresses the HBM->VMEM
schedule that a CUDA kernel would express with threadblocks.  K is kept
un-tiled because every model dimension in this repo fits VMEM (d <= 4096:
bm*K + K*bn + bm*bn floats < 4 MiB for bm=bn=128).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the AOT HLO artifacts must run on the Rust CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def _pick_block(n, pref=128):
    """Largest power-of-2 block <= pref that divides n."""
    b = 1
    while b * 2 <= min(n, pref) and n % (b * 2) == 0:
        b *= 2
    return b


def matmul_pallas(x, w, *, bm=128, bn=128):
    """(m, k) @ (k, n) -> (m, n) via the Pallas kernel.

    Arbitrary shapes are supported by shrinking block sizes to divisors;
    shapes in this repo are powers of 2 so blocks stay MXU-aligned 128x128.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul shape mismatch {x.shape} @ {w.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def linear_matmul(x, w):
    """Differentiable linear-layer matmul: Pallas forward, jnp backward."""
    return matmul_pallas(x, w)


def _linear_fwd(x, w):
    return matmul_pallas(x, w), (x, w)


def _linear_bwd(res, g):
    x, w = res
    return jnp.matmul(g, w.T), jnp.matmul(x.T, g)


linear_matmul.defvjp(_linear_fwd, _linear_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul_jit(x, w, bm=128, bn=128):
    return matmul_pallas(x, w, bm=bm, bn=bn)
