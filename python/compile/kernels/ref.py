"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(python/tests/) checks the Pallas outputs against these with hypothesis
shape/dtype sweeps, and the Rust side's golden tests are generated from the
same functions.
"""

import jax.numpy as jnp


def ref_matmul(x, w):
    """Plain matmul oracle for kernels.matmul."""
    return jnp.matmul(x, w)


def ref_fwht(x):
    """Normalized fast Walsh-Hadamard transform along the last axis.

    x: (..., d) with d a power of 2.  Equivalent to x @ H_d / sqrt(d) with
    the Sylvester-ordered Hadamard matrix H_d (H is symmetric so left/right
    application coincide for row vectors).
    """
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"FWHT needs power-of-2 dim, got {d}"
    orig_shape = x.shape
    y = x.reshape(-1, d)
    h = 1
    while h < d:
        y = y.reshape(-1, d // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2).reshape(-1, d)
        h *= 2
    return (y / jnp.sqrt(jnp.asarray(d, x.dtype))).reshape(orig_shape)


def ref_rht(x, sign):
    """Randomized Hadamard transform: FWHT(x * sign) along last axis.

    sign: (d,) vector of +-1 Rademacher samples (the diagonal D).
    """
    return ref_fwht(x * sign)


def ref_rabitq_quantize(v, bits):
    """RaBitQ grid quantization of columns of v (d, c) -> (codes, r).

    Matches kernels.rabitq: per-column max-abs scale, round to the b-bit
    unsigned grid, then per-column least-squares rescale r so that
    v[:, j] ~= r[j] * (codes[:, j] - c_b).

    Returns codes as float32 carrying integers in [0, 2^bits - 1] and
    r (c,) float32.
    """
    cb = (2.0**bits - 1.0) / 2.0
    maxabs = jnp.max(jnp.abs(v), axis=0)  # (c,)
    t = jnp.where(maxabs > 0, maxabs / cb, 1.0)
    codes = jnp.clip(jnp.round(v / t + cb), 0.0, 2.0**bits - 1.0)
    q = codes - cb
    num = jnp.sum(v * q, axis=0)
    den = jnp.sum(q * q, axis=0)
    r = jnp.where(den > 0, num / den, 0.0)
    return codes.astype(jnp.float32), r.astype(jnp.float32)


def ref_qmatmul(x, codes, r, bits):
    """Algorithm 3 (paper): estimate X @ W from quantized codes.

    x:     (n, d) already-RHT-rotated inputs  X' = Hadamard(D X^T)^T
    codes: (d, c) integer codes (stored as float32)
    r:     (c,)   per-column rescale factors
    Returns (n, c): per column j, y_j = r_j * (X' @ codes_j - c_b * X' @ 1).
    """
    cb = (2.0**bits - 1.0) / 2.0
    z = cb * jnp.sum(x, axis=1, keepdims=True)  # (n, 1) = c_b * X 1
    return (jnp.matmul(x, codes) - z) * r[None, :]


def ref_dequantize(codes, r, bits):
    """Reconstruct the effective (rotated-space) weight matrix r*(codes-c_b)."""
    cb = (2.0**bits - 1.0) / 2.0
    return (codes - cb) * r[None, :]
