"""L1 Pallas kernel: in-VMEM fast Walsh-Hadamard transform (FWHT).

The paper's RaBitQ-H replaces RaBitQ's O(d^2) random rotation with a
Randomized Hadamard Transform computed by a fast kernel (HadaCore-style on
GPU).  TPU re-think (DESIGN.md section "Hardware adaptation"): instead of
staging the butterfly through 48 KiB of shared memory per threadblock, each
Pallas grid step holds a (block_rows, d) tile in VMEM and runs all log2(d)
butterfly stages in-register before writing back — for d <= 4096 whole rows
fit, so there is no inter-block exchange at all.

The stage loop is a Python while (d is static), so the lowered HLO is a
flat chain of reshape/add/sub — fuses into one elementwise pass per stage.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_rows(y, d):
    """Apply the unnormalized FWHT butterfly to each row of y (r, d)."""
    h = 1
    while h < d:
        y = y.reshape(-1, d // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2).reshape(-1, d)
        h *= 2
    return y


def _fwht_kernel(x_ref, o_ref, *, d):
    y = _fwht_rows(x_ref[...], d)
    o_ref[...] = y * (1.0 / jnp.sqrt(jnp.asarray(d, o_ref.dtype)))


def _rht_kernel(x_ref, sign_ref, o_ref, *, d):
    y = _fwht_rows(x_ref[...] * sign_ref[...], d)
    o_ref[...] = y * (1.0 / jnp.sqrt(jnp.asarray(d, o_ref.dtype)))


def _pick_rows(n_rows, d, budget_floats=1 << 20):
    """Block row count: fit two (rows, d) tiles in a ~8 MiB VMEM budget."""
    rows = max(1, budget_floats // (2 * d))
    b = 1
    while b * 2 <= min(rows, n_rows) and n_rows % (b * 2) == 0:
        b *= 2
    return b


def fwht_pallas(x):
    """Normalized FWHT along the last axis of x (..., d); d power of 2."""
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"FWHT needs power-of-2 dim, got {d}"
    shape = x.shape
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    br = _pick_rows(n, d)
    out = pl.pallas_call(
        lambda x_ref, o_ref: _fwht_kernel(x_ref, o_ref, d=d),
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x2)
    return out.reshape(shape)


def rht_pallas(x, sign):
    """Randomized Hadamard transform FWHT(x * sign) along the last axis.

    sign: (d,) Rademacher +-1 vector (the diagonal D of the paper's Alg. 2).
    Fused into the same kernel so the sign flip never round-trips to HBM.
    """
    d = x.shape[-1]
    assert d & (d - 1) == 0, f"RHT needs power-of-2 dim, got {d}"
    assert sign.shape == (d,)
    shape = x.shape
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    br = _pick_rows(n, d)
    out = pl.pallas_call(
        lambda x_ref, s_ref, o_ref: _rht_kernel(x_ref, s_ref, o_ref, d=d),
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x2, sign)
    return out.reshape(shape)
