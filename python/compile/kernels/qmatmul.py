"""L1 Pallas kernel: fused Algorithm-3 dequant-matmul (paper Alg. 3).

Computes  Y = (X' @ (codes - c_b)) * r  =  (X' @ codes - z) * r,
z = c_b * X' @ 1, without ever materializing the dequantized weight matrix:
codes stay in their storage dtype in HBM, are upcast inside the kernel
block, centered by c_b, fed to the MXU, and the per-column rescale r is
applied on the VPU epilogue.  This is the TPU analog of RaBitQ's
"compute on codes without decompression".

The row-sum term z is computed from the same X' tile already resident in
VMEM, so the fusion saves one full pass over X'.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmatmul_kernel(x_ref, c_ref, r_ref, o_ref, *, cb):
    x = x_ref[...]
    codes = c_ref[...].astype(x.dtype)
    acc = jnp.dot(x, codes, preferred_element_type=o_ref.dtype)
    z = cb * jnp.sum(x, axis=1, keepdims=True)
    o_ref[...] = (acc - z) * r_ref[...][None, :]


def _pick_block(n, pref=128):
    b = 1
    while b * 2 <= min(n, pref) and n % (b * 2) == 0:
        b *= 2
    return b


def qmatmul_pallas(x, codes, r, *, bits, bm=128, bn=128):
    """Estimate X @ W_hat from RaBitQ-H codes.

    x:     (n, d) RHT-rotated activations X' (float)
    codes: (d, c) quantization codes (any numeric dtype; values in
           [0, 2^bits - 1])
    r:     (c,)   per-column rescale factors (float)
    """
    n, d = x.shape
    d2, c = codes.shape
    assert d == d2 and r.shape == (c,)
    cb = (2.0**bits - 1.0) / 2.0
    bm = _pick_block(n, bm)
    bn = _pick_block(c, bn)
    grid = (n // bm, c // bn)
    return pl.pallas_call(
        functools.partial(_qmatmul_kernel, cb=cb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=True,
    )(x, codes, r)


@functools.partial(jax.jit, static_argnames=("bits",))
def qmatmul_jit(x, codes, r, bits):
    return qmatmul_pallas(x, codes, r, bits=bits)
