"""L2: GPT-style byte-level transformer in JAX, calling the L1 Pallas kernels.

This is the model the Rust coordinator trains, calibrates, quantizes, and
serves.  Every registered linear layer (attention q/k/v/o and MLP fc1/fc2)
routes through kernels.matmul.linear_matmul — Pallas forward, jnp backward —
so the same kernel lowers into every AOT artifact while gradients still flow
for training and for the paper's calibration quantities (eq. 23):

    alpha_k = (1/sqrt(d_k)) * ||dL/dH^(k)||_F * ||X^(k)||_F * ||W^(k)||_F

`loss_with_dummies` injects a zero dummy into each linear-layer output so a
single jax.grad call yields all dL/dH^(k) at once; `calib_grads` reduces
them to the Frobenius norms the Rust side consumes.

Entry points lowered by aot.py (all shapes static per ModelConfig):
    init_params   (seed)                        -> params
    train_step    (params, m, v, step, lr, tok) -> (params, m, v, loss)
    fwd_loss      (params, tok)                 -> per-token loss (B, S-1)
    fwd_logits    (params, tok)                 -> last-position logits (B, V)
    calib_grads   (params, tok)                 -> (gnorms (L,), xnorms (L,))
    calib_capture (params, tok)                 -> per-layer inputs X_k
"""

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.matmul import linear_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    vocab: int = 256          # byte-level tokenizer
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seq_len: int = 128
    train_batch: int = 8
    eval_batch: int = 8
    calib_batch: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


CONFIGS = {
    "tiny": ModelConfig(name="tiny", d_model=256, n_layers=4, n_heads=4,
                        d_ff=1024),
    "small": ModelConfig(name="small", d_model=512, n_layers=6, n_heads=8,
                         d_ff=2048),
    # Micro config for fast pytest of the full artifact path.
    "micro": ModelConfig(name="micro", d_model=64, n_layers=2, n_heads=2,
                         d_ff=256, seq_len=32, train_batch=2, eval_batch=2),
}


# ---------------------------------------------------------------------------
# Parameter schema
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic flat (name, shape) list — the artifact input order."""
    d, dff, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (v, d)),
        ("pos_emb", (s, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"blk{i}."
        specs += [
            (p + "ln1.scale", (d,)), (p + "ln1.bias", (d,)),
            (p + "attn.wq", (d, d)), (p + "attn.wq.b", (d,)),
            (p + "attn.wk", (d, d)), (p + "attn.wk.b", (d,)),
            (p + "attn.wv", (d, d)), (p + "attn.wv.b", (d,)),
            (p + "attn.wo", (d, d)), (p + "attn.wo.b", (d,)),
            (p + "ln2.scale", (d,)), (p + "ln2.bias", (d,)),
            (p + "mlp.fc1", (d, dff)), (p + "mlp.fc1.b", (dff,)),
            (p + "mlp.fc2", (dff, d)), (p + "mlp.fc2.b", (d,)),
        ]
    specs += [("ln_f.scale", (d,)), ("ln_f.bias", (d,)), ("lm_head", (d, v))]
    return specs


def linear_registry(cfg: ModelConfig) -> List[Dict]:
    """The L quantization targets, in forward order (paper's k = 1..L).

    Embeddings, LayerNorms and lm_head stay full precision (standard PTQ
    practice and what the paper's LLaMA setup does for non-linear params).
    """
    regs = []
    for i in range(cfg.n_layers):
        for nm, din, dout in [
            ("attn.wq", cfg.d_model, cfg.d_model),
            ("attn.wk", cfg.d_model, cfg.d_model),
            ("attn.wv", cfg.d_model, cfg.d_model),
            ("attn.wo", cfg.d_model, cfg.d_model),
            ("mlp.fc1", cfg.d_model, cfg.d_ff),
            ("mlp.fc2", cfg.d_ff, cfg.d_model),
        ]:
            regs.append({
                "name": f"blk{i}.{nm}",
                "param": f"blk{i}.{nm}",
                # Linear-layer biases exist so the paper's centralization
                # trick (App. C.3) can fold its rank-1 correction term
                # (W - W_hat)^T s_hat into the bias at dequantization time.
                "bias": f"blk{i}.{nm}.b",
                "d": din,
                "c": dout,
                "m": din * dout,
            })
    return regs


def init_params(cfg: ModelConfig, seed) -> List[jnp.ndarray]:
    """GPT-2-style init; returns params in param_specs order."""
    key = jax.random.PRNGKey(seed)
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    out = []
    for (name, shape), k in zip(specs, keys):
        if name.endswith(".scale"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".bias") or name.endswith(".b"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) == 2 else shape[-1]
            std = 0.02 if "emb" in name else 1.0 / jnp.sqrt(fan_in)
            # Residual-branch projections get the GPT-2 depth scaling.
            if name.endswith("attn.wo") or name.endswith("mlp.fc2"):
                std = std / jnp.sqrt(2.0 * cfg.n_layers)
            out.append(std * jax.random.normal(k, shape, jnp.float32))
    return out


def params_dict(cfg: ModelConfig, flat: List[jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {name: arr for (name, _), arr in zip(param_specs(cfg), flat)}


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def forward(cfg: ModelConfig, p: Dict[str, jnp.ndarray], tokens,
            dummies=None, capture=None):
    """Token logits.  tokens: (B, S) int32.

    dummies: optional list of L arrays added to each registered linear
      output H_k (all-zero at evaluation; jax.grad w.r.t. them gives
      dL/dH_k for the paper's sensitivity estimate).
    capture: optional list that receives each linear input X_k (B*S, d_k).
    """
    B, S = tokens.shape
    d = cfg.d_model
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :S, :]
    li = 0  # linear-layer index into the registry order

    def lin(x2d, wname):
        nonlocal li
        if capture is not None:
            capture.append(x2d)
        out = linear_matmul(x2d, p[wname]) + p[wname + ".b"][None, :]
        if dummies is not None:
            out = out + dummies[li]
        li += 1
        return out

    mask = jnp.tril(jnp.ones((S, S), jnp.bool_))
    for i in range(cfg.n_layers):
        pre = f"blk{i}."
        x = _layer_norm(h, p[pre + "ln1.scale"], p[pre + "ln1.bias"])
        x2 = x.reshape(B * S, d)
        q = lin(x2, pre + "attn.wq").reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = lin(x2, pre + "attn.wk").reshape(B, S, cfg.n_heads, cfg.head_dim)
        v = lin(x2, pre + "attn.wv").reshape(B, S, cfg.n_heads, cfg.head_dim)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
            jnp.asarray(cfg.head_dim, jnp.float32))
        att = jnp.where(mask[None, None, :, :], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B * S, d)
        h = h + lin(o, pre + "attn.wo").reshape(B, S, d)

        x = _layer_norm(h, p[pre + "ln2.scale"], p[pre + "ln2.bias"])
        y = lin(x.reshape(B * S, d), pre + "mlp.fc1")
        y = jax.nn.gelu(y)
        h = h + lin(y, pre + "mlp.fc2").reshape(B, S, d)

    h = _layer_norm(h, p["ln_f.scale"], p["ln_f.bias"])
    logits = jnp.matmul(h, p["lm_head"])  # (B, S, V) — lm_head stays fp
    return logits


def token_losses(cfg: ModelConfig, p, tokens, dummies=None, capture=None):
    """Per-token next-token cross-entropy, (B, S-1)."""
    logits = forward(cfg, p, tokens, dummies=dummies, capture=capture)
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return nll


def mean_loss(cfg: ModelConfig, flat_params, tokens):
    p = params_dict(cfg, flat_params)
    return jnp.mean(token_losses(cfg, p, tokens))


# ---------------------------------------------------------------------------
# Entry points (lowered by aot.py)
# ---------------------------------------------------------------------------

def make_dummies(cfg: ModelConfig, batch: int):
    """Zero arrays shaped like each registered linear output H_k."""
    n = batch * cfg.seq_len
    return [jnp.zeros((n, reg["c"]), jnp.float32)
            for reg in linear_registry(cfg)]


def loss_with_dummies(cfg: ModelConfig, flat_params, dummies, tokens):
    p = params_dict(cfg, flat_params)
    capture: list = []
    nll = token_losses(cfg, p, tokens, dummies=dummies, capture=capture)
    xnorms = jnp.stack([jnp.linalg.norm(x) for x in capture])
    return jnp.mean(nll), xnorms


def calib_grads(cfg: ModelConfig, flat_params, tokens):
    """(gnorms, xnorms): ||dL/dH_k||_F and ||X_k||_F for every linear k."""
    dummies = make_dummies(cfg, tokens.shape[0])
    grad_fn = jax.grad(lambda dm: loss_with_dummies(cfg, flat_params, dm,
                                                    tokens)[0])
    grads = grad_fn(dummies)
    gnorms = jnp.stack([jnp.linalg.norm(g) for g in grads])
    _, xnorms = loss_with_dummies(cfg, flat_params, dummies, tokens)
    return gnorms, xnorms


def calib_capture(cfg: ModelConfig, flat_params, tokens):
    """(loss, X_1, ..., X_L) — per-layer linear inputs; the GPTQ baseline
    builds X^T X from these.

    The loss is returned (not just computed) so every parameter stays live
    in the lowered HLO: XLA prunes unused entry parameters at compile time,
    which would otherwise shrink the artifact's input arity (lm_head, final
    LayerNorm and the last block's fc2 don't influence the captures).
    """
    p = params_dict(cfg, flat_params)
    capture: list = []
    nll = token_losses(cfg, p, tokens, capture=capture)
    return (jnp.mean(nll),) + tuple(capture)


def fwd_loss(cfg: ModelConfig, flat_params, tokens):
    p = params_dict(cfg, flat_params)
    return token_losses(cfg, p, tokens)


def fwd_logits(cfg: ModelConfig, flat_params, tokens):
    p = params_dict(cfg, flat_params)
    logits = forward(cfg, p, tokens)
    return logits[:, -1, :]  # (B, V) — the generation step only needs last


# AdamW (decoupled weight decay); betas/eps/wd baked, lr a runtime scalar.
ADAM_B1, ADAM_B2, ADAM_EPS, ADAM_WD = 0.9, 0.999, 1e-8, 0.01


def train_step(cfg: ModelConfig, flat_params, flat_m, flat_v, step, lr,
               tokens):
    """One AdamW step.  Returns (params', m', v', loss)."""
    loss, grads = jax.value_and_grad(
        lambda fp: mean_loss(cfg, fp, tokens))(flat_params)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    specs = param_specs(cfg)
    new_p, new_m, new_v = [], [], []
    for (name, _), pth, g, m, v in zip(specs, flat_params, grads, flat_m,
                                       flat_v):
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + ADAM_EPS)
        decay = 0.0 if (name.endswith(".bias") or name.endswith(".scale")
                        or name.endswith(".b") or "emb" in name) else ADAM_WD
        new_p.append(pth - lr * (upd + decay * pth))
        new_m.append(m2)
        new_v.append(v2)
    return tuple(new_p), tuple(new_m), tuple(new_v), loss
