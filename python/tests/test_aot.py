"""AOT lowering round-trip: every artifact must lower to parseable HLO text
and report the declared I/O arity in its ENTRY signature."""

import json
import os
import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M


CFG = M.CONFIGS["micro"]


def _entry_params(hlo_text):
    """Parameter instructions of the ENTRY computation.

    HLO text from this XLA version puts the signature in
    `entry_computation_layout=...` and opens ENTRY with `ENTRY main.N {`;
    we count `parameter(i)` instructions inside the ENTRY block.
    """
    m = re.search(r"^ENTRY .*\{", hlo_text, flags=re.M)
    assert m, "no ENTRY found"
    body = hlo_text[m.end():]
    return re.findall(r"parameter\(\d+\)", body)


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.lower_model(CFG, str(out / CFG.name))
    return out / CFG.name


def test_all_artifacts_written(artifacts):
    for name in ("init_params", "train_step", "fwd_loss", "fwd_logits",
                 "calib_grads", "calib_capture"):
        path = artifacts / f"{name}.hlo.txt"
        assert path.exists(), name
        text = path.read_text()
        assert text.startswith("HloModule"), name


def test_manifest_schema(artifacts):
    man = json.loads((artifacts / "manifest.json").read_text())
    assert man["model"]["name"] == CFG.name
    assert len(man["params"]) == len(M.param_specs(CFG))
    assert len(man["linears"]) == 6 * CFG.n_layers
    for p, (name, shape) in zip(man["params"], M.param_specs(CFG)):
        assert p["name"] == name and tuple(p["shape"]) == tuple(shape)


def test_init_params_arity(artifacts):
    text = (artifacts / "init_params.hlo.txt").read_text()
    assert len(_entry_params(text)) == 1  # seed


def test_train_step_arity(artifacts):
    text = (artifacts / "train_step.hlo.txt").read_text()
    n = len(M.param_specs(CFG))
    assert len(_entry_params(text)) == 3 * n + 3


def test_fwd_loss_arity(artifacts):
    text = (artifacts / "fwd_loss.hlo.txt").read_text()
    n = len(M.param_specs(CFG))
    assert len(_entry_params(text)) == n + 1


def test_calib_grads_arity(artifacts):
    text = (artifacts / "calib_grads.hlo.txt").read_text()
    n = len(M.param_specs(CFG))
    assert len(_entry_params(text)) == n + 1


def test_hlo_has_no_serialized_proto_markers(artifacts):
    """Guard the text-interchange invariant (DESIGN.md): artifacts must be
    HLO text, parseable by xla_extension 0.5.1."""
    text = (artifacts / "fwd_loss.hlo.txt").read_text()
    assert "HloModule" in text.splitlines()[0]


def test_kernel_artifact_lowering(tmp_path):
    aot.lower_kernels(str(tmp_path))
    files = os.listdir(tmp_path)
    for n, d, c, bits in aot.QMATMUL_SHAPES:
        assert f"qmatmul_{n}x{d}x{c}_b{bits}.hlo.txt" in files
    for n, d in aot.HADAMARD_SHAPES:
        assert f"hadamard_{n}x{d}.hlo.txt" in files
