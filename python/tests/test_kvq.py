"""Quantized-KV mirror suite (numpy-only — runs where jax is absent).

The Rust `kvq` subsystem (rotate-per-head → RaBitQ-quantize → pack →
attend-over-codes) has no rustc in some containers, so its *logic* is
validated here through the strict-f32 Python mirror in ``gen_vectors.py``
— the same functions that emit the ``kvq_attend.json`` golden vectors the
Rust side is pinned against. Three jobs:

1. mirror self-checks: the practical RHT is orthonormal and inverts, the
   quantizer's rescale is least-squares optimal, reconstruction error
   decays ~2^-bits;
2. the accuracy contract of the whole quantize→attend path: **bounded
   drift** against exact f32/f64 attention at 8 bits and a **monotone
   2 → 4 → 8-bit quality ladder** (EXPERIMENTS.md §KV compression);
3. the committed golden vectors are internally consistent (softmax
   weights well-formed, codes in range), so a bad generator cannot pin a
   bad kernel.
"""

import json

import numpy as np
import pytest

import gen_vectors as gv

VEC = gv.VECTOR_DIR


def _mk_rng(seed):
    return np.random.default_rng(seed)


def _rand_f32(rng, n, scale=1.5):
    return [gv.f32(x) for x in rng.uniform(-scale, scale, size=n)]


def _signs(rng, head_dim):
    d_hat = gv.floor_pow2(head_dim)
    signs1 = [float(s) for s in rng.choice((-1.0, 1.0), size=d_hat)]
    signs2 = ([] if d_hat == head_dim
              else [float(s) for s in rng.choice((-1.0, 1.0), size=d_hat)])
    return signs1, signs2


def _attend_exact(q, k, v, ctx, heads, head_dim):
    """Exact (float64) multi-head attention over the raw rows."""
    d = heads * head_dim
    q = np.asarray(q, dtype=np.float64)
    k = np.asarray(k, dtype=np.float64).reshape(ctx, d)
    v = np.asarray(v, dtype=np.float64).reshape(ctx, d)
    out = np.zeros(d)
    for h in range(heads):
        sl = slice(h * head_dim, (h + 1) * head_dim)
        s = k[:, sl] @ q[sl] / np.sqrt(head_dim)
        w = np.exp(s - s.max())
        w /= w.sum()
        out[sl] = w @ v[:, sl]
    return out


def _attend_quantized(q, k, v, ctx, heads, head_dim, bits, signs1, signs2):
    """The full mirror path: quantize rows per (row, head), attend over
    the codes — what `QuantizedKvStore::store_row` + `attend_cached_q`
    compute."""
    kc, kr = gv.kvq_quantize_rows(k, ctx, heads, head_dim, bits, signs1, signs2)
    vc, vr = gv.kvq_quantize_rows(v, ctx, heads, head_dim, bits, signs1, signs2)
    return np.asarray(gv.kvq_attend_ref(
        q, kc, kr, vc, vr, ctx, heads, head_dim, bits, bits, signs1, signs2))


# ------------------------------------------------------------ mirror checks

@pytest.mark.parametrize("head_dim", [4, 5, 8, 12, 16])
def test_practical_rht_is_orthonormal_and_inverts(head_dim):
    rng = _mk_rng(head_dim)
    signs1, signs2 = _signs(rng, head_dim)
    x = np.asarray(_rand_f32(rng, head_dim), dtype=np.float32)
    y = gv.practical_rht_f32(x, signs1, signs2)
    np.testing.assert_allclose(np.linalg.norm(y), np.linalg.norm(x), rtol=1e-5)
    back = gv.practical_rht_inv_f64(y.astype(np.float64), signs1, signs2)
    np.testing.assert_allclose(back, x.astype(np.float64), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("bits", [1, 2, 3, 4, 5, 8])
def test_quantizer_codes_in_range_and_r_is_least_squares(bits):
    rng = _mk_rng(100 + bits)
    seg = _rand_f32(rng, 64)
    codes, r = gv.rabitq_quantize_maxabs_f32(seg, bits)
    assert all(0 <= c <= 2 ** bits - 1 for c in codes)
    cb = (2 ** bits - 1) / 2.0
    qv = np.asarray(codes, dtype=np.float64) - cb
    x = np.asarray(seg, dtype=np.float64)

    def err(rr):
        return float(np.sum((x - rr * qv) ** 2))

    # perturbing r either way must not reduce the reconstruction error
    assert err(r) <= err(r * 1.01) + 1e-9
    assert err(r) <= err(r * 0.99) + 1e-9
    # zero column: centered codes, r = 0
    z_codes, z_r = gv.rabitq_quantize_maxabs_f32([0.0] * 8, bits)
    assert z_r == 0.0
    assert all(c == int(np.floor(cb)) for c in z_codes)


def test_quantizer_reconstruction_decays_with_bits():
    rng = _mk_rng(7)
    seg = _rand_f32(rng, 256)
    x = np.asarray(seg, dtype=np.float64)
    prev = np.inf
    for bits in range(1, 9):
        codes, r = gv.rabitq_quantize_maxabs_f32(seg, bits)
        cb = (2 ** bits - 1) / 2.0
        rec = r * (np.asarray(codes, dtype=np.float64) - cb)
        rel = np.linalg.norm(x - rec) / np.linalg.norm(x)
        assert rel < prev * 1.05, f"bits={bits}: {rel} !< {prev}"
        assert rel < 3.0 * 2.0 ** -bits, f"bits={bits} rel={rel}"
        prev = rel


# ------------------------------------------------- the accuracy contract

def test_attend_over_codes_monotone_quality_ladder():
    """The monotone 2 -> 4 -> 8-bit ladder, averaged over seeds: the
    quantize→attend drift against exact attention must strictly shrink as
    bits grow, and 8-bit must be tight (bounded drift, not exactness)."""
    heads, head_dim, ctx = 2, 16, 12
    d = heads * head_dim
    errs = {2: [], 4: [], 8: []}
    for seed in range(6):
        rng = _mk_rng(1000 + seed)
        signs1, signs2 = _signs(rng, head_dim)
        q = _rand_f32(rng, d)
        k = _rand_f32(rng, ctx * d)
        v = _rand_f32(rng, ctx * d)
        exact = _attend_exact(q, k, v, ctx, heads, head_dim)
        norm = np.linalg.norm(exact)
        for bits in (2, 4, 8):
            got = _attend_quantized(q, k, v, ctx, heads, head_dim, bits,
                                    signs1, signs2)
            errs[bits].append(float(np.linalg.norm(got - exact) / norm))
    mean = {b: np.mean(errs[b]) for b in errs}
    assert mean[2] > mean[4] > mean[8], f"ladder not monotone: {mean}"
    assert mean[8] < 0.05, f"8-bit drift too large: {mean[8]}"
    assert mean[4] < 0.25, f"4-bit drift too large: {mean[4]}"


def test_attend_over_codes_nonpow2_head_dim():
    """Non-pow2 head dims ride the two overlapping RHT windows; the path
    must stay well-conditioned there too."""
    heads, head_dim, ctx = 2, 12, 8
    d = heads * head_dim
    rng = _mk_rng(77)
    signs1, signs2 = _signs(rng, head_dim)
    assert signs2, "non-pow2 head_dim must use the second window"
    q = _rand_f32(rng, d)
    k = _rand_f32(rng, ctx * d)
    v = _rand_f32(rng, ctx * d)
    exact = _attend_exact(q, k, v, ctx, heads, head_dim)
    got = _attend_quantized(q, k, v, ctx, heads, head_dim, 8, signs1, signs2)
    rel = np.linalg.norm(got - exact) / np.linalg.norm(exact)
    assert rel < 0.05, f"8-bit drift at head_dim=12: {rel}"


def test_ctx1_is_value_reconstruction():
    """One cached row: the softmax weight is exactly 1, so the attend
    output is the V row's quantized reconstruction."""
    heads, head_dim = 2, 8
    d = heads * head_dim
    rng = _mk_rng(5)
    signs1, signs2 = _signs(rng, head_dim)
    q = _rand_f32(rng, d)
    k = _rand_f32(rng, d)
    v = _rand_f32(rng, d)
    got = _attend_quantized(q, k, v, 1, heads, head_dim, 8, signs1, signs2)
    np.testing.assert_allclose(got, np.asarray(v, dtype=np.float64),
                               rtol=0.05, atol=0.05)


# ------------------------------------------------- committed golden vectors

def test_kvq_vectors_are_internally_consistent():
    doc = json.loads((VEC / "kvq_attend.json").read_text())
    assert len(doc["cases"]) >= 5
    nonpow2 = False
    for case in doc["cases"]:
        heads, hd, ctx = case["heads"], case["head_dim"], case["ctx"]
        kb, vb = case["k_bits"], case["v_bits"]
        d = heads * hd
        nonpow2 |= hd & (hd - 1) != 0
        assert len(case["k_codes"]) == ctx * d
        assert len(case["k_r"]) == ctx * heads
        assert all(0 <= c <= 2 ** kb - 1 for c in case["k_codes"])
        assert all(0 <= c <= 2 ** vb - 1 for c in case["v_codes"])
        assert len(case["signs1"]) == gv.floor_pow2(hd)
        assert all(s in (-1.0, 1.0) for s in case["signs1"] + case["signs2"])
        # regenerating the codes from the committed inputs must agree
        kc, kr = gv.kvq_quantize_rows(case["k"], ctx, heads, hd, kb,
                                      case["signs1"], case["signs2"])
        assert kc == case["k_codes"]
        np.testing.assert_allclose(kr, case["k_r"], rtol=1e-6, atol=1e-9)
        # and the attend output must match the committed one exactly
        out = gv.kvq_attend_ref(case["q"], case["k_codes"], case["k_r"],
                                case["v_codes"], case["v_r"], ctx, heads, hd,
                                kb, vb, case["signs1"], case["signs2"])
        np.testing.assert_allclose(out, case["out"], rtol=1e-12, atol=1e-12)
    assert nonpow2, "vectors must cover a non-pow2 head_dim"
