"""Numpy mirror of the cluster scatter-gather merge (``cluster::merge``).

The Rust router's determinism contract: a collection sharded round-robin
across N workers must answer top-k queries **bit-identically** to a
single node holding the same rows. The Rust side pins that end to end
over real sockets (``rust/tests/cluster.rs``); this mirror pins the
merge *math* against the same committed fixture
(``rust/tests/vectors/cluster_merge.json``) so the contract is checkable
from a Python-only container:

1. every pinned stage of the fixture (per-shard local top-take, global
   candidate selection, exact-score merge) must match an independent
   recomputation from the raw ``est``/``exact`` arrays;
2. the distributed pipeline must equal the single-node two-phase query
   (global top-take by estimated score, exact rerank, top-k) — the
   bit-identity claim at the ordering level;
3. the fixture's scores must be f32-exact and tie-free, so the pinned
   order is unambiguous and survives the f32 wire format.

Needs only numpy (runs in the minimal ``python-tests`` CI flavor).
"""

import json

import numpy as np

import gen_vectors as gv

FIXTURE = gv.VECTOR_DIR / "cluster_merge.json"


def load():
    assert FIXTURE.exists(), (
        f"{FIXTURE} missing — run python/tests/gen_vectors.py"
    )
    return json.loads(FIXTURE.read_text())


def shard_of(gid, n_shards):
    return gid % n_shards


def local_of(gid, n_shards):
    return gid // n_shards


def global_of(shard, local, n_shards):
    return local * n_shards + shard


def shard_rows(shard, n_shards, n):
    return n // n_shards + (1 if shard < n % n_shards else 0)


def top_take(scores, ids, take):
    """(score desc, id asc) truncated to ``take`` — the one ordering the
    whole pipeline uses (mirrors ``index::top_indices`` and the router's
    candidate/merge sorts)."""
    order = sorted(range(len(ids)), key=lambda i: (-scores[i], ids[i]))
    return [(ids[i], scores[i]) for i in order[:take]]


def test_fixture_stages_match_recomputation():
    doc = load()
    n, n_shards = doc["n"], doc["n_shards"]
    k, rf, take = doc["k"], doc["rerank_factor"], doc["take"]
    est, exact = doc["est"], doc["exact"]
    assert len(est) == n and len(exact) == n
    assert take == min(max(rf, 1) * k, n)

    # stage 1: per-shard local top-take over the shard's est slice
    selected = []
    for s, pinned in enumerate(doc["per_shard_candidates"]):
        rows = shard_rows(s, n_shards, n)
        local_est = [est[global_of(s, l, n_shards)] for l in range(rows)]
        got = top_take(local_est, list(range(rows)), take)
        assert [(h["id"], h["score"]) for h in pinned] == got, f"shard {s}"
        selected += [(sc, global_of(s, l, n_shards)) for l, sc in got]

    # stage 2: global candidate selection by (est desc, gid asc)
    gids = [g for g, _ in top_take([sc for sc, _ in selected],
                                   [g for _, g in selected], take)]
    assert gids == doc["selected_gids"]

    # stage 3: exact-score merge by (exact desc, gid asc), truncate k
    merged = top_take([exact[g] for g in gids], gids, k)
    assert [(h["id"], h["score"]) for h in doc["merged"]] == merged


def test_distributed_merge_equals_single_node_two_phase():
    doc = load()
    n, k, take = doc["n"], doc["k"], doc["take"]
    est, exact = doc["est"], doc["exact"]

    # a single node's two-phase query: global top-take by est, exact
    # rerank of those candidates, top-k by exact score
    cand = [g for g, _ in top_take(est, list(range(n)), take)]
    single = top_take([exact[g] for g in cand], cand, k)

    assert [(h["id"], h["score"]) for h in doc["merged"]] == single, (
        "distributed merge drifted from the single-node two-phase order"
    )


def test_partition_is_a_bijection():
    doc = load()
    n, n_shards = doc["n"], doc["n_shards"]
    seen = set()
    for s in range(n_shards):
        for l in range(shard_rows(s, n_shards, n)):
            g = global_of(s, l, n_shards)
            assert shard_of(g, n_shards) == s and local_of(g, n_shards) == l
            seen.add(g)
    assert seen == set(range(n))


def test_scores_are_f32_exact_and_tie_free():
    doc = load()
    for key in ("est", "exact"):
        xs = doc[key]
        # f32-exact: the committed f64 text must survive an f32 round
        # trip unchanged, or the wire format would reorder candidates
        assert all(float(np.float32(x)) == x for x in xs), key
        # tie-free with a real gap: the pinned order never depends on
        # how a consumer breaks score ties
        srt = sorted(xs)
        assert all(b - a > 1e-3 for a, b in zip(srt, srt[1:])), key
