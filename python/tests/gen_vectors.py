#!/usr/bin/env python3
"""Golden-vector generator: the committed cross-language kernel contract.

Writes small JSON vectors into ``rust/tests/vectors/`` for the three
kernels whose Rust implementations previously had only an ad-hoc Python
f32 mirror: the orthonormal FWHT, the packed-code bit decoders (widths
1-8, including non-byte-aligned tails), and ``attend_cached``. The Rust
side (``rust/tests/golden.rs``) consumes them, so the equivalence is
checkable both from a Python-only container (regenerate + diff, see
``--check``) and from a Rust-only CI job (consume + compare).

Determinism contract: data comes from ``random.Random`` (Mersenne
Twister, stable across Python versions and platforms), f32 rounding goes
through numpy, and the JSON is emitted with sorted keys — regenerating
must be byte-identical to the committed files, which ``--check`` (and
``test_vectors.py``) enforces.

Usage:
    python python/tests/gen_vectors.py           # (re)write the vectors
    python python/tests/gen_vectors.py --check   # verify committed files
"""

import json
import random
import sys
from pathlib import Path

import numpy as np

VECTOR_DIR = Path(__file__).resolve().parents[2] / "rust" / "tests" / "vectors"


def f32(x):
    """Round to f32 and back to a Python float (exact in JSON)."""
    return float(np.float32(x))


def rand_f32_list(rng, n, scale=2.0):
    """Deterministic pseudo-gaussian-ish f32 values in (-scale, scale)."""
    return [f32(rng.uniform(-scale, scale)) for _ in range(n)]


# --------------------------------------------------------------------- FWHT

def fwht_f32(values):
    """Orthonormal FWHT in strict float32, mirroring `hadamard::fwht`:
    butterfly stages of elementwise a+b / a-b (one IEEE op per output per
    stage, so no reassociation anywhere), then a single multiply by
    1/sqrt(d) computed in f32."""
    x = np.asarray(values, dtype=np.float32).copy()
    d = x.size
    h = 1
    while h < d:
        x = x.reshape(-1, 2 * h)
        a = x[:, :h].copy()
        b = x[:, h:].copy()
        x[:, :h] = a + b
        x[:, h:] = a - b
        x = x.reshape(-1)
        h *= 2
    scale = np.float32(1.0) / np.sqrt(np.float32(d))
    return [float(v) for v in x * scale]


def gen_fwht():
    rng = random.Random(0xF147)
    cases = []
    for d in (1, 2, 4, 8, 32, 128):
        for _ in range(2):
            inp = rand_f32_list(rng, d)
            cases.append({"d": d, "input": inp, "output": fwht_f32(inp)})
    return {"kernel": "fwht", "cases": cases}


# ------------------------------------------------------------- bit decoders

def pack_lsb_first(values, bits):
    """Mirror of `rabitq::PackedCodes::pack`: LSB-first within each byte."""
    data = bytearray((len(values) * bits + 7) // 8)
    for i, v in enumerate(values):
        assert 0 <= v < (1 << bits)
        bit0 = i * bits
        byte0, off = divmod(bit0, 8)
        w = v << off
        data[byte0] |= w & 0xFF
        if off + bits > 8:
            data[byte0 + 1] |= (w >> 8) & 0xFF
    return list(data)


def gen_decode():
    rng = random.Random(0xDEC0)
    cases = []
    for bits in range(1, 9):
        # deliberately not a multiple of 8/bits: the packed payload ends in
        # a partial byte for every width that allows one
        n = 61
        values = [rng.randrange(1 << bits) for _ in range(n)]
        reads = []
        # whole range, offset head, unaligned mid-range, single tail
        # element, empty read — the shapes `decode_codes_into` special-cases
        for start, ln in ((0, n), (1, n - 1), (7, 40), (n - 1, 1), (3, 0)):
            reads.append({
                "start": start,
                "len": ln,
                "expect": values[start:start + ln],
            })
        cases.append({
            "bits": bits,
            "values": values,
            "data": pack_lsb_first(values, bits),
            "reads": reads,
        })
    return {"kernel": "decode_codes", "cases": cases}


# ------------------------------------------------------------ attend_cached

def attend_ref(q, k_rows, v_rows, ctx, heads, head_dim):
    """Float64 reference of `kernels::attend_cached`: per head, scaled
    dot-product scores over all ctx keys, max-shifted softmax, weighted
    value sum. The Rust kernel runs in f32, so the consumer compares with
    the same 1e-4 tolerance its in-crate reference test uses."""
    d = heads * head_dim
    q = np.asarray(q, dtype=np.float64)
    k = np.asarray(k_rows, dtype=np.float64).reshape(ctx, d)
    v = np.asarray(v_rows, dtype=np.float64).reshape(ctx, d)
    out = np.zeros(d)
    for h in range(heads):
        sl = slice(h * head_dim, (h + 1) * head_dim)
        scores = k[:, sl] @ q[sl] / np.sqrt(head_dim)
        scores = np.exp(scores - scores.max())
        weights = scores / scores.sum()
        out[sl] = weights @ v[:, sl]
    return [float(x) for x in out]


def gen_attend():
    rng = random.Random(0xA77E)
    cases = []
    for heads, head_dim, ctx in ((1, 4, 1), (2, 4, 5), (4, 8, 12), (2, 16, 3)):
        d = heads * head_dim
        q = rand_f32_list(rng, d, 1.5)
        k = rand_f32_list(rng, ctx * d, 1.5)
        v = rand_f32_list(rng, ctx * d, 1.5)
        cases.append({
            "heads": heads,
            "head_dim": head_dim,
            "ctx": ctx,
            "q": q,
            "k": k,
            "v": v,
            "out": attend_ref(q, k, v, ctx, heads, head_dim),
        })
    return {"kernel": "attend_cached", "cases": cases}


# ----------------------------------------------------------------- harness

GENERATORS = {
    "fwht.json": gen_fwht,
    "decode_codes.json": gen_decode,
    "attend_cached.json": gen_attend,
}


def render(doc):
    return json.dumps(doc, sort_keys=True, separators=(",", ":")) + "\n"


def main(argv):
    check = "--check" in argv
    VECTOR_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for name, gen in GENERATORS.items():
        path = VECTOR_DIR / name
        text = render(gen())
        if check:
            committed = path.read_text() if path.exists() else None
            if committed != text:
                failures.append(name)
            else:
                print(f"ok: {name} matches regeneration")
        else:
            path.write_text(text)
            print(f"wrote {path} ({len(text)} bytes)")
    if failures:
        print(f"STALE golden vectors: {failures} — rerun gen_vectors.py", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
