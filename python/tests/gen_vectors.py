#!/usr/bin/env python3
"""Golden-vector generator: the committed cross-language kernel contract.

Writes small JSON vectors into ``rust/tests/vectors/`` for the three
kernels whose Rust implementations previously had only an ad-hoc Python
f32 mirror: the orthonormal FWHT, the packed-code bit decoders (widths
1-8, including non-byte-aligned tails), and ``attend_cached``. The Rust
side (``rust/tests/golden.rs``) consumes them, so the equivalence is
checkable both from a Python-only container (regenerate + diff, see
``--check``) and from a Rust-only CI job (consume + compare).

Determinism contract: data comes from ``random.Random`` (Mersenne
Twister, stable across Python versions and platforms), f32 rounding goes
through numpy, and the JSON is emitted with sorted keys — regenerating
must be byte-identical to the committed files, which ``--check`` (and
``test_vectors.py``) enforces.

Usage:
    python python/tests/gen_vectors.py           # (re)write the vectors
    python python/tests/gen_vectors.py --check   # verify committed files
"""

import json
import random
import struct
import sys
import zlib
from pathlib import Path

import numpy as np

VECTOR_DIR = Path(__file__).resolve().parents[2] / "rust" / "tests" / "vectors"


def f32(x):
    """Round to f32 and back to a Python float (exact in JSON)."""
    return float(np.float32(x))


def rand_f32_list(rng, n, scale=2.0):
    """Deterministic pseudo-gaussian-ish f32 values in (-scale, scale)."""
    return [f32(rng.uniform(-scale, scale)) for _ in range(n)]


# --------------------------------------------------------------------- FWHT

def fwht_f32(values):
    """Orthonormal FWHT in strict float32, mirroring `hadamard::fwht`:
    butterfly stages of elementwise a+b / a-b (one IEEE op per output per
    stage, so no reassociation anywhere), then a single multiply by
    1/sqrt(d) computed in f32."""
    x = np.asarray(values, dtype=np.float32).copy()
    d = x.size
    h = 1
    while h < d:
        x = x.reshape(-1, 2 * h)
        a = x[:, :h].copy()
        b = x[:, h:].copy()
        x[:, :h] = a + b
        x[:, h:] = a - b
        x = x.reshape(-1)
        h *= 2
    scale = np.float32(1.0) / np.sqrt(np.float32(d))
    return [float(v) for v in x * scale]


def gen_fwht():
    rng = random.Random(0xF147)
    cases = []
    for d in (1, 2, 4, 8, 32, 128):
        for _ in range(2):
            inp = rand_f32_list(rng, d)
            cases.append({"d": d, "input": inp, "output": fwht_f32(inp)})
    return {"kernel": "fwht", "cases": cases}


# ------------------------------------------------------------- bit decoders

def pack_lsb_first(values, bits):
    """Mirror of `rabitq::PackedCodes::pack`: LSB-first within each byte."""
    data = bytearray((len(values) * bits + 7) // 8)
    for i, v in enumerate(values):
        assert 0 <= v < (1 << bits)
        bit0 = i * bits
        byte0, off = divmod(bit0, 8)
        w = v << off
        data[byte0] |= w & 0xFF
        if off + bits > 8:
            data[byte0 + 1] |= (w >> 8) & 0xFF
    return list(data)


def gen_decode():
    rng = random.Random(0xDEC0)
    cases = []
    for bits in range(1, 9):
        # deliberately not a multiple of 8/bits: the packed payload ends in
        # a partial byte for every width that allows one
        n = 61
        values = [rng.randrange(1 << bits) for _ in range(n)]
        reads = []
        # whole range, offset head, unaligned mid-range, single tail
        # element, empty read — the shapes `decode_codes_into` special-cases
        for start, ln in ((0, n), (1, n - 1), (7, 40), (n - 1, 1), (3, 0)):
            reads.append({
                "start": start,
                "len": ln,
                "expect": values[start:start + ln],
            })
        cases.append({
            "bits": bits,
            "values": values,
            "data": pack_lsb_first(values, bits),
            "reads": reads,
        })
    # second case per width, drawn from an independent stream so the base
    # cases above stay byte-identical: n = 37 ends mid-byte for every
    # width except 8 (where alignment is structural), and the reads stop
    # and start inside the bulk body so the width-specialized decoders
    # can't silently change tail handling
    rng_tail = random.Random(0xDEC1)
    for bits in range(1, 9):
        n = 37
        values = [rng_tail.randrange(1 << bits) for _ in range(n)]
        reads = []
        # whole range, truncated tail, mid-range stopping short of the
        # end, short unaligned window, two-element tail
        for start, ln in ((0, n), (0, n - 3), (5, n - 7), (2, 9), (n - 2, 2)):
            reads.append({
                "start": start,
                "len": ln,
                "expect": values[start:start + ln],
            })
        cases.append({
            "bits": bits,
            "values": values,
            "data": pack_lsb_first(values, bits),
            "reads": reads,
        })
    return {"kernel": "decode_codes", "cases": cases}


# ------------------------------------------------------------ attend_cached

def attend_ref(q, k_rows, v_rows, ctx, heads, head_dim):
    """Float64 reference of `kernels::attend_cached`: per head, scaled
    dot-product scores over all ctx keys, max-shifted softmax, weighted
    value sum. The Rust kernel runs in f32, so the consumer compares with
    the same 1e-4 tolerance its in-crate reference test uses."""
    d = heads * head_dim
    q = np.asarray(q, dtype=np.float64)
    k = np.asarray(k_rows, dtype=np.float64).reshape(ctx, d)
    v = np.asarray(v_rows, dtype=np.float64).reshape(ctx, d)
    out = np.zeros(d)
    for h in range(heads):
        sl = slice(h * head_dim, (h + 1) * head_dim)
        scores = k[:, sl] @ q[sl] / np.sqrt(head_dim)
        scores = np.exp(scores - scores.max())
        weights = scores / scores.sum()
        out[sl] = weights @ v[:, sl]
    return [float(x) for x in out]


def gen_attend():
    rng = random.Random(0xA77E)
    cases = []
    for heads, head_dim, ctx in ((1, 4, 1), (2, 4, 5), (4, 8, 12), (2, 16, 3)):
        d = heads * head_dim
        q = rand_f32_list(rng, d, 1.5)
        k = rand_f32_list(rng, ctx * d, 1.5)
        v = rand_f32_list(rng, ctx * d, 1.5)
        cases.append({
            "heads": heads,
            "head_dim": head_dim,
            "ctx": ctx,
            "q": q,
            "k": k,
            "v": v,
            "out": attend_ref(q, k, v, ctx, heads, head_dim),
        })
    return {"kernel": "attend_cached", "cases": cases}


# --------------------------------------------------- kvq: quantize + attend

def floor_pow2(n):
    """Largest power of two <= n (mirror of hadamard::floor_pow2)."""
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def practical_rht_f32(values, signs1, signs2):
    """Mirror of `hadamard::PracticalRht::apply` in strict f32: RHT (sign
    multiply, then the orthonormal FWHT) over the first d_hat entries, then
    over the last d_hat entries (windows overlap when d is not a power of
    2; signs2 is empty when it is). Single IEEE f32 op per output per
    stage, same order as the Rust butterfly — bit-exact by construction."""
    x = np.asarray(values, dtype=np.float32).copy()
    d = x.size
    d_hat = len(signs1)

    def rht_window(seg, signs):
        seg = (seg * np.asarray(signs, dtype=np.float32)).astype(np.float32)
        return np.asarray(fwht_f32(seg), dtype=np.float32)

    x[:d_hat] = rht_window(x[:d_hat], signs1)
    if signs2:
        x[d - d_hat:] = rht_window(x[d - d_hat:], signs2)
    return x


def round_half_away_f32(s):
    """f32 round-half-away-from-zero for non-negative inputs (mirror of
    Rust `f32::round` on the quantizer's shifted values, which are always
    >= 0 under max-abs scaling). `s - floor(s)` is exact in f32 for the
    magnitudes here (< 2^23), so the half test is exact."""
    s = np.asarray(s, dtype=np.float32)
    fl = np.floor(s).astype(np.float32)
    frac = (s - fl).astype(np.float32)
    return np.where(frac >= np.float32(0.5), fl + np.float32(1.0), fl).astype(np.float32)


def rabitq_quantize_maxabs_f32(seg, bits):
    """Mirror of `rabitq::quantize_column_into` at ScaleMode::MaxAbs:
    strict-f32 code arithmetic (scale, shift, round, clamp — one IEEE op
    each, same order as Rust), f64 accumulation for the least-squares
    rescale. Returns (codes as ints, r as an f32-rounded float)."""
    x = np.asarray(seg, dtype=np.float32)
    cb = np.float32((2 ** bits - 1) / 2.0)
    maxv = np.float32(2 ** bits - 1)
    maxabs = np.float32(np.max(np.abs(x))) if x.size else np.float32(0.0)
    if maxabs == np.float32(0.0):
        return [int(np.floor(cb))] * x.size, 0.0
    base_t = np.float32(maxabs / cb)
    inv_t = np.float32(np.float32(1.0) / base_t)
    codes = []
    vq = 0.0
    qq = 0.0
    for xi in x:
        s = np.float32(np.float32(xi * inv_t) + cb)
        code = float(np.clip(round_half_away_f32(s), np.float32(0.0), maxv))
        qf = np.float32(np.float32(code) - cb)
        vq += float(xi) * float(qf)
        qq += float(qf) * float(qf)
        codes.append(int(code))
    r = f32(vq / qq) if qq > 0.0 else 0.0
    return codes, r


def fwht_f64(values):
    """Orthonormal FWHT in float64 (reference side of the attend mirror)."""
    x = np.asarray(values, dtype=np.float64).copy()
    d = x.size
    h = 1
    while h < d:
        x = x.reshape(-1, 2 * h)
        a = x[:, :h].copy()
        b = x[:, h:].copy()
        x[:, :h] = a + b
        x[:, h:] = a - b
        x = x.reshape(-1)
        h *= 2
    return x / np.sqrt(d)


def practical_rht_inv_f64(values, signs1, signs2):
    """Float64 inverse of the practical RHT (window 2 first, then 1;
    inverse RHT = FWHT then sign multiply)."""
    x = np.asarray(values, dtype=np.float64).copy()
    d = x.size
    d_hat = len(signs1)
    if signs2:
        seg = fwht_f64(x[d - d_hat:]) * np.asarray(signs2, dtype=np.float64)
        x[d - d_hat:] = seg
    x[:d_hat] = fwht_f64(x[:d_hat]) * np.asarray(signs1, dtype=np.float64)
    return x


def kvq_quantize_rows(rows, ctx, heads, head_dim, bits, signs1, signs2):
    """Rotate + quantize every (row, head) segment — the
    `kvq::QuantizedKvStore::store_row` recipe. Returns (codes flat per row,
    r per (row, head))."""
    d = heads * head_dim
    codes = []
    rs = []
    for ki in range(ctx):
        for h in range(heads):
            seg = rows[ki * d + h * head_dim:ki * d + (h + 1) * head_dim]
            rot = practical_rht_f32(seg, signs1, signs2)
            c, r = rabitq_quantize_maxabs_f32(rot, bits)
            codes.extend(c)
            rs.append(r)
    return codes, rs


def kvq_attend_ref(q, k_codes, k_r, v_codes, v_r, ctx, heads, head_dim,
                   k_bits, v_bits, signs1, signs2):
    """Float64 reference of `kernels::attend_cached_q` given exact codes:
    rotate q per head (strict f32, like the kernel), estimate scores from
    K codes, softmax, mix V codes in rotated space, inverse-rotate."""
    d = heads * head_dim
    cbk = (2 ** k_bits - 1) / 2.0
    cbv = (2 ** v_bits - 1) / 2.0
    out = np.zeros(d)
    kc = np.asarray(k_codes, dtype=np.float64).reshape(ctx, d)
    vc = np.asarray(v_codes, dtype=np.float64).reshape(ctx, d)
    for h in range(heads):
        sl = slice(h * head_dim, (h + 1) * head_dim)
        q_rot = practical_rht_f32(np.asarray(q, dtype=np.float32)[sl],
                                  signs1, signs2).astype(np.float64)
        qsum = q_rot.sum()
        rk = np.asarray([k_r[ki * heads + h] for ki in range(ctx)], dtype=np.float64)
        scores = rk * (kc[:, sl] @ q_rot - cbk * qsum) / np.sqrt(head_dim)
        w = np.exp(scores - scores.max())
        w /= w.sum()
        rv = np.asarray([v_r[ki * heads + h] for ki in range(ctx)], dtype=np.float64)
        wr = w * rv
        acc = wr @ vc[:, sl] - cbv * wr.sum()
        out[sl] = practical_rht_inv_f64(acc, signs1, signs2)
    return [float(x) for x in out]


def gen_kvq():
    rng = random.Random(0x6B76)
    cases = []
    # (heads, head_dim, ctx, k_bits, v_bits): pow2 and non-pow2 head dims
    # (the latter exercise both practical-RHT windows), plus widths whose
    # packed rows end mid-byte (unaligned head-dim tails)
    shapes = (
        (2, 8, 5, 8, 8),
        (2, 8, 6, 4, 2),
        (4, 16, 9, 4, 4),
        (2, 5, 7, 5, 3),
        (1, 12, 4, 3, 6),
    )
    for heads, head_dim, ctx, k_bits, v_bits in shapes:
        d = heads * head_dim
        d_hat = floor_pow2(head_dim)
        signs1 = [float(rng.choice((-1.0, 1.0))) for _ in range(d_hat)]
        signs2 = ([] if d_hat == head_dim
                  else [float(rng.choice((-1.0, 1.0))) for _ in range(d_hat)])
        q = rand_f32_list(rng, d, 1.5)
        k = rand_f32_list(rng, ctx * d, 1.5)
        v = rand_f32_list(rng, ctx * d, 1.5)
        k_codes, k_r = kvq_quantize_rows(k, ctx, heads, head_dim, k_bits, signs1, signs2)
        v_codes, v_r = kvq_quantize_rows(v, ctx, heads, head_dim, v_bits, signs1, signs2)
        out = kvq_attend_ref(q, k_codes, k_r, v_codes, v_r, ctx, heads, head_dim,
                             k_bits, v_bits, signs1, signs2)
        cases.append({
            "heads": heads,
            "head_dim": head_dim,
            "ctx": ctx,
            "k_bits": k_bits,
            "v_bits": v_bits,
            "signs1": signs1,
            "signs2": signs2,
            "q": q,
            "k": k,
            "v": v,
            "k_codes": k_codes,
            "k_r": k_r,
            "v_codes": v_codes,
            "v_r": v_r,
            "out": out,
        })
    return {"kernel": "kvq_attend", "cases": cases}


# ------------------------------------------------- index: scan + top-k

def index_quantize_rows(rows, n, d, bits, signs1, signs2):
    """Rotate + quantize each full row — the `index::Collection` store
    recipe (full-dimension practical RHT, MaxAbs grid, one rescale per
    row; metric normalization happens before this step and is not part
    of the vectors). Returns (codes flat, r per row)."""
    codes = []
    rs = []
    for i in range(n):
        seg = rows[i * d:(i + 1) * d]
        rot = practical_rht_f32(seg, signs1, signs2)
        c, r = rabitq_quantize_maxabs_f32(rot, bits)
        codes.extend(c)
        rs.append(r)
    return codes, rs


def index_scan_ref(q, codes, rs, n, d, bits, signs1, signs2):
    """Float64 reference of `kernels::scan_scores_q`: rotate the query
    (strict f32, like the kernel), then per row the Algorithm-3 estimate
    `r * (<q_rot, codes> - c_b * sum(q_rot))`."""
    cb = (2 ** bits - 1) / 2.0
    q_rot = practical_rht_f32(q, signs1, signs2).astype(np.float64)
    qsum = q_rot.sum()
    c = np.asarray(codes, dtype=np.float64).reshape(n, d)
    scores = np.asarray(rs, dtype=np.float64) * (c @ q_rot - cb * qsum)
    return [float(x) for x in scores]


def index_top_k(scores, k):
    """Mirror of `index::top_indices`: descending score, ties broken
    toward the lower index."""
    return sorted(range(len(scores)), key=lambda i: (-scores[i], i))[:k]


def index_exact_scores(q, rows, n, d):
    """Exact f64 inner products (the brute-force baseline / rerank)."""
    r = np.asarray(rows, dtype=np.float64).reshape(n, d)
    return [float(x) for x in r @ np.asarray(q, dtype=np.float64)]


def gen_index():
    rng = random.Random(0x1DE8)
    cases = []
    # (n, d, bits, k): pow2 and non-pow2 dims (the latter exercise both
    # practical-RHT windows), plus widths whose packed rows end mid-byte
    shapes = (
        (12, 16, 8, 5),
        (10, 24, 4, 5),
        (8, 20, 5, 4),
        (16, 32, 2, 5),
        (9, 12, 3, 3),
    )
    for n, d, bits, k in shapes:
        d_hat = floor_pow2(d)
        signs1 = [float(rng.choice((-1.0, 1.0))) for _ in range(d_hat)]
        signs2 = ([] if d_hat == d
                  else [float(rng.choice((-1.0, 1.0))) for _ in range(d_hat)])
        rows = rand_f32_list(rng, n * d, 1.5)
        q = rand_f32_list(rng, d, 1.5)
        codes, rs = index_quantize_rows(rows, n, d, bits, signs1, signs2)
        est = index_scan_ref(q, codes, rs, n, d, bits, signs1, signs2)
        # the consumer asserts the committed top-k ORDER: require clear
        # gaps around and inside the top-k so f32-vs-f64 arithmetic
        # differences cannot reorder it (deterministic data, so this is
        # a generation-time invariant, not a flaky retry)
        ranked = sorted(est, reverse=True)
        gaps = [ranked[i] - ranked[i + 1] for i in range(min(k, len(ranked) - 1))]
        assert min(gaps) > 2e-3, (
            f"top-{k} gap too small for a pinned order (n={n} d={d} "
            f"bits={bits}): {min(gaps)}"
        )
        cases.append({
            "n": n,
            "d": d,
            "bits": bits,
            "k": k,
            "signs1": signs1,
            "signs2": signs2,
            "rows": rows,
            "q": q,
            "codes": codes,
            "data": pack_lsb_first(codes, bits),
            "r": rs,
            "est_scores": est,
            "exact_scores": index_exact_scores(q, rows, n, d),
            "topk": index_top_k(est, k),
        })
    return {"kernel": "index_search", "cases": cases}


# ------------------------- durability: WAL + segment + manifest bytes

def f32_bytes(values):
    """Little-endian f32 serialization of exact-f32 Python floats."""
    return np.asarray(values, dtype="<f4").tobytes()


def wal_record(seq, name, dim, rows):
    """Mirror of `index::wal::encode_record`: `[len u32][crc u32]` then a
    payload of `[kind=1][seq u64][name_len u16][name][dim u32][nrows u32]
    [rows f32 LE]`. The CRC is zlib-compatible CRC-32 over the payload."""
    payload = bytes([1]) + struct.pack("<Q", seq)
    payload += struct.pack("<H", len(name)) + name.encode()
    payload += struct.pack("<II", dim, len(rows) // dim)
    payload += f32_bytes(rows)
    return struct.pack("<II", len(payload), zlib.crc32(payload)) + payload


def durability_collection(name, d, bits, signs1, signs2, exact_rows):
    """Flattened collection state under Metric::InnerProduct (no row
    normalization): the residual store IS the input rows, codes and
    rescales come from the shared index quantization recipe — how the
    rows end up split between sealed segments and the head does not
    change this canonical form."""
    n = len(exact_rows) // d
    codes, rs = index_quantize_rows(exact_rows, n, d, bits, signs1, signs2)
    return {
        "name": name,
        "d": d,
        "bits": bits,
        "signs1": signs1,
        "signs2": signs2,
        "codes": bytes(pack_lsb_first(codes, bits)),
        "r": rs,
        "exact": exact_rows,
    }


def snapshot_bytes(next_seq, rows_at_solve, collections):
    """Mirror of `index::snapshot::encode_snapshot` (the RQSN v1 format —
    no longer written to disk, but kept as the canonical LOGICAL encoding
    every recovery expectation is asserted through): header,
    per-collection blocks in name order, whole-body CRC-32."""
    out = bytearray(b"RQSN")
    out += struct.pack("<I", 1)
    out += struct.pack("<QQ", next_seq, rows_at_solve)
    out += struct.pack("<I", len(collections))
    for c in sorted(collections, key=lambda c: c["name"]):
        out += struct.pack("<H", len(c["name"])) + c["name"].encode()
        out += struct.pack("<I", c["d"]) + bytes([c["bits"], 0])  # metric 0 = ip
        out += struct.pack("<I", len(c["signs1"])) + f32_bytes(c["signs1"])
        out += struct.pack("<I", len(c["signs2"])) + f32_bytes(c["signs2"])
        out += struct.pack("<II", len(c["r"]), len(c["codes"]))
        out += bytes(c["codes"])
        out += f32_bytes(c["r"])
        out += f32_bytes(c["exact"])
    out += struct.pack("<I", zlib.crc32(bytes(out)))
    return bytes(out)


def segment_file(name, seg_id):
    """Mirror of `segment_file_name`: the id is zero-padded and parsed
    from the END (collection names may contain '-')."""
    return f"segments/{name}-{seg_id:020d}.seg"


def segment_bytes(name, seg_id, d, bits, exact_rows, signs1, signs2):
    """Mirror of `index::segment::encode_segment` (the RQSG v1 format):
    one sealed head — per-segment packed codes, rescales, residual rows —
    under Metric::InnerProduct, CRC-32 tail."""
    n = len(exact_rows) // d
    codes, rs = index_quantize_rows(exact_rows, n, d, bits, signs1, signs2)
    packed = bytes(pack_lsb_first(codes, bits))
    out = bytearray(b"RQSG")
    out += struct.pack("<I", 1)
    out += struct.pack("<H", len(name)) + name.encode()
    out += struct.pack("<Q", seg_id)
    out += struct.pack("<I", d) + bytes([bits, 0])  # metric 0 = ip
    out += struct.pack("<I", n)
    out += struct.pack("<I", len(packed)) + packed
    out += f32_bytes(rs)
    out += f32_bytes(exact_rows)
    out += struct.pack("<I", zlib.crc32(bytes(out)))
    return bytes(out)


def manifest_file(gen):
    """Mirror of `manifest_file_name`: zero-padded so lexicographic order
    is generation order."""
    return f"manifest-{gen:020d}.mf"


def manifest_bytes(gen, next_seq, next_seg_id, rows_at_solve, collections):
    """Mirror of `index::segment::encode_manifest` (the RQMF v1 format):
    store header, then per collection (strict name order) its config,
    sign diagonals, and the ordered list of live segment references
    `(id, rows, bits)` — a per-segment bits below the collection's marks
    a file recovery must requantize. CRC-32 tail."""
    out = bytearray(b"RQMF")
    out += struct.pack("<I", 1)
    out += struct.pack("<QQQQ", gen, next_seq, next_seg_id, rows_at_solve)
    out += struct.pack("<I", len(collections))
    for c in sorted(collections, key=lambda c: c["name"]):
        out += struct.pack("<H", len(c["name"])) + c["name"].encode()
        out += struct.pack("<I", c["d"]) + bytes([c["bits"], 0])  # metric 0 = ip
        out += struct.pack("<I", len(c["signs1"])) + f32_bytes(c["signs1"])
        out += struct.pack("<I", len(c["signs2"])) + f32_bytes(c["signs2"])
        out += struct.pack("<I", len(c["segments"]))
        for sid, rows, sbits in c["segments"]:
            out += struct.pack("<Q", sid) + struct.pack("<I", rows) + bytes([sbits])
    out += struct.pack("<I", zlib.crc32(bytes(out)))
    return bytes(out)


def gen_durability():
    """Recovery edge cases as committed byte-level fixtures. Each case is
    a data directory (relative path -> hex bytes: a manifest, its segment
    files, and WAL tails) plus the exact recovery outcome: the report
    counters and — the decisive cross-language check — the canonical
    re-encoding of the recovered store, computed here with numpy and
    asserted byte-identical by the Rust consumer
    (`rust/tests/durability.rs`) after it recovers the same directory.

    All cases use Metric ip (no normalization to mirror) and a Uniform
    bit plan (no rebalance cadence), and WAL records only ever target
    collections already present in the manifest — fresh-collection sign
    diagonals are RNG-derived on the Rust side and not mirrorable, which
    is exactly why the manifest serializes signs instead of seeds."""
    rng = random.Random(0xD04A)
    d, bits = 16, 6
    signs1 = [float(rng.choice((-1.0, 1.0))) for _ in range(d)]
    signs2 = []

    def rows_of(n):
        return rand_f32_list(rng, n * d, 1.5)

    def col(exact_rows, name="docs", dd=None, s1=None):
        return durability_collection(
            name, dd or d, bits, s1 or signs1, signs2, exact_rows)

    def seg(seg_id, exact_rows, name="docs", dd=None, s1=None):
        return segment_bytes(name, seg_id, dd or d, bits, exact_rows,
                             s1 or signs1, signs2)

    def mcol(segments, name="docs", dd=None, s1=None):
        return {"name": name, "d": dd or d, "bits": bits,
                "signs1": s1 or signs1, "signs2": signs2,
                "segments": segments}

    def expect(snap, replay, dropped, dup, corrupt, next_seq, rows, reenc):
        return {
            "snapshot_rows": snap,
            "replayed_rows": replay,
            "dropped_records": dropped,
            "duplicate_records": dup,
            "corrupt_snapshots": corrupt,
            "next_seq": next_seq,
            "rows": rows,
            "reencoded_snapshot": reenc.hex(),
        }

    cases = []

    # 1. empty WAL beside a sealed generation: a clean zero-record file,
    # nothing to replay, nothing dropped
    sealed = rows_of(3)
    cases.append({
        "name": "empty-wal",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 3, 2, 0,
                                                   [mcol([(1, 3, bits)])]).hex(),
                  segment_file("docs", 1): seg(1, sealed).hex(),
                  "wal/docs.wal": ""},
        "expect": expect(3, 0, 0, 0, 0, 3, 3, snapshot_bytes(3, 0, [col(sealed)])),
    })

    # 2. manifest + segment only, no WAL directory at all (the state
    # right after a seal committed and deleted the logs)
    sealed = rows_of(2)
    cases.append({
        "name": "manifest-only",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 2, 2, 0,
                                                   [mcol([(1, 2, bits)])]).hex(),
                  segment_file("docs", 1): seg(1, sealed).hex()},
        "expect": expect(2, 0, 0, 0, 0, 2, 2, snapshot_bytes(2, 0, [col(sealed)])),
    })

    # 3. torn mid-record tail: two whole records replay, the truncated
    # third is one dropped tail (the normal crash shape)
    sealed = rows_of(2)
    r2, r3, r4 = rows_of(1), rows_of(2), rows_of(1)
    wal = wal_record(2, "docs", d, r2) + wal_record(3, "docs", d, r3)
    wal += wal_record(4, "docs", d, r4)[:13]  # header + 5 payload bytes
    final = col(sealed + r2 + r3)
    cases.append({
        "name": "torn-mid-record-tail",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 2, 2, 0,
                                                   [mcol([(1, 2, bits)])]).hex(),
                  segment_file("docs", 1): seg(1, sealed).hex(),
                  "wal/docs.wal": wal.hex()},
        "expect": expect(2, 3, 1, 0, 0, 4, 5, snapshot_bytes(4, 0, [final])),
    })

    # 4. duplicate replay idempotence: a WAL record the manifest already
    # covers (seq below next_seq) is skipped, never double-applied
    sealed = rows_of(2)
    new = rows_of(1)
    wal = wal_record(1, "docs", d, sealed[d:]) + wal_record(2, "docs", d, new)
    final = col(sealed + new)
    cases.append({
        "name": "duplicate-replay",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 2, 2, 0,
                                                   [mcol([(1, 2, bits)])]).hex(),
                  segment_file("docs", 1): seg(1, sealed).hex(),
                  "wal/docs.wal": wal.hex()},
        "expect": expect(2, 1, 0, 1, 0, 3, 3, snapshot_bytes(3, 0, [final])),
    })

    # 5. checksum mismatch: a flipped payload bit fails the CRC and ends
    # the replayable prefix (stop-at-first-corruption)
    sealed = rows_of(1)
    good = rows_of(1)
    bad = bytearray(wal_record(2, "docs", d, rows_of(1)))
    bad[12] ^= 0x20  # inside the payload's seq field
    wal = wal_record(1, "docs", d, good) + bytes(bad)
    final = col(sealed + good)
    cases.append({
        "name": "checksum-mismatch",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 1, 2, 0,
                                                   [mcol([(1, 1, bits)])]).hex(),
                  segment_file("docs", 1): seg(1, sealed).hex(),
                  "wal/docs.wal": wal.hex()},
        "expect": expect(1, 1, 1, 0, 0, 2, 2, snapshot_bytes(2, 0, [final])),
    })

    # 6. corrupt newest manifest: recovery skips that generation
    # (counted), falls back to the kept predecessor, the WAL still
    # covers the gap, and the newer generation's segment file is simply
    # an unreferenced orphan
    sealed = rows_of(2)
    extra = rows_of(1)
    newest = bytearray(manifest_bytes(2, 3, 3, 0,
                                      [mcol([(1, 2, bits), (2, 1, bits)])]))
    newest[20] ^= 0x01  # CRC catches the flip; the generation is skipped
    cases.append({
        "name": "corrupt-manifest-fallback",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 2, 2, 0,
                                                   [mcol([(1, 2, bits)])]).hex(),
                  manifest_file(2): bytes(newest).hex(),
                  segment_file("docs", 1): seg(1, sealed).hex(),
                  segment_file("docs", 2): seg(2, extra).hex(),
                  "wal/docs.wal": wal_record(2, "docs", d, extra).hex()},
        "expect": expect(2, 1, 0, 0, 1, 3, 3,
                         snapshot_bytes(3, 0, [col(sealed + extra)])),
    })

    # 7. interleaved collections: per-collection WAL files merge back by
    # the store-global seq, and the manifest's name order is canonical
    d2 = 8
    s_alpha = [float(rng.choice((-1.0, 1.0))) for _ in range(d2)]
    s_beta = [float(rng.choice((-1.0, 1.0))) for _ in range(d2)]
    a0 = rand_f32_list(rng, d2, 1.5)
    b1 = rand_f32_list(rng, d2, 1.5)
    b2 = rand_f32_list(rng, d2, 1.5)
    a3 = rand_f32_list(rng, d2, 1.5)
    manifest = manifest_bytes(1, 2, 3, 0, [
        mcol([(1, 1, bits)], "alpha", d2, s_alpha),
        mcol([(2, 1, bits)], "beta", d2, s_beta),
    ])
    final_cols = [col(a0 + a3, "alpha", d2, s_alpha),
                  col(b1 + b2, "beta", d2, s_beta)]
    cases.append({
        "name": "interleaved-collections",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest.hex(),
                  segment_file("alpha", 1): seg(1, a0, "alpha", d2, s_alpha).hex(),
                  segment_file("beta", 2): seg(2, b1, "beta", d2, s_beta).hex(),
                  "wal/beta.wal": wal_record(2, "beta", d2, b2).hex(),
                  "wal/alpha.wal": wal_record(3, "alpha", d2, a3).hex()},
        "expect": expect(2, 2, 0, 0, 0, 4, 4, snapshot_bytes(4, 0, final_cols)),
    })

    return {"kernel": "durability_recovery", "cases": cases}


def gen_segments():
    """Segment-format edge cases (`rust/tests/segments.rs` consumes
    these): scatter across several sealed segments, the stale-width
    requantize path, orphan tolerance, and whole-generation rejection on
    a missing or corrupt referenced segment. d = 10 on purpose — the
    practical RHT uses both (overlapping) windows, and at 5 bits a row
    is 50 bits, so rows share bytes and the per-segment packing differs
    from the flattened canonical packing (which pins that recovery
    really repacks, not concatenates)."""
    rng = random.Random(0x5E65)
    d, bits = 10, 5
    d_hat = floor_pow2(d)
    signs1 = [float(rng.choice((-1.0, 1.0))) for _ in range(d_hat)]
    signs2 = [float(rng.choice((-1.0, 1.0))) for _ in range(d_hat)]

    def rows_of(n):
        return rand_f32_list(rng, n * d, 1.5)

    def col(exact_rows, b=bits):
        return durability_collection("docs", d, b, signs1, signs2, exact_rows)

    def seg(seg_id, exact_rows, b=bits):
        return segment_bytes("docs", seg_id, d, b, exact_rows, signs1, signs2)

    def mcol(segments, b=bits):
        return {"name": "docs", "d": d, "bits": b,
                "signs1": signs1, "signs2": signs2, "segments": segments}

    def expect(snap, replay, dropped, corrupt, next_seq, rows, segments, reenc):
        return {
            "snapshot_rows": snap,
            "replayed_rows": replay,
            "dropped_records": dropped,
            "corrupt_snapshots": corrupt,
            "next_seq": next_seq,
            "rows": rows,
            "segments": segments,
            "reencoded_snapshot": reenc.hex(),
        }

    cases = []

    # 1. scatter across two sealed segments + a WAL tail into the head:
    # the canonical re-encoding flattens all three, repacking codes
    # across the segment boundaries
    seg_a, seg_b, tail = rows_of(2), rows_of(3), rows_of(1)
    cases.append({
        "name": "multi-segment-scatter",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 5, 3, 0,
                                                   [mcol([(1, 2, bits),
                                                          (2, 3, bits)])]).hex(),
                  segment_file("docs", 1): seg(1, seg_a).hex(),
                  segment_file("docs", 2): seg(2, seg_b).hex(),
                  "wal/docs.wal": wal_record(5, "docs", d, tail).hex()},
        "expect": expect(5, 1, 0, 0, 6, 6, 2,
                         snapshot_bytes(6, 0, [col(seg_a + seg_b + tail)])),
    })

    # 2. stale width: the manifest says the collection runs at 3 bits
    # but the file on disk was sealed at 5 (a rebalance narrowed the
    # plan after the seal; compaction has not rewritten the file yet) —
    # recovery must requantize the segment's rows from its residual
    # store, bit-identical to a fresh 3-bit encode
    stale = rows_of(2)
    cases.append({
        "name": "stale-width-requantize",
        "bits": 3,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 2, 2, 0,
                                                   [mcol([(1, 2, bits)],
                                                         b=3)]).hex(),
                  segment_file("docs", 1): seg(1, stale, b=bits).hex()},
        "expect": expect(2, 0, 0, 0, 2, 2, 1, snapshot_bytes(2, 0, [col(stale, b=3)])),
    })

    # 3. an orphan segment file (valid bytes, but no manifest references
    # it — a crash between a segment write and its manifest commit) is
    # ignored entirely
    live, orphan, tail = rows_of(2), rows_of(1), rows_of(1)
    cases.append({
        "name": "orphan-segment-ignored",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): manifest_bytes(1, 2, 2, 0,
                                                   [mcol([(1, 2, bits)])]).hex(),
                  segment_file("docs", 1): seg(1, live).hex(),
                  segment_file("docs", 7): seg(7, orphan).hex(),
                  "wal/docs.wal": wal_record(2, "docs", d, tail).hex()},
        "expect": expect(2, 1, 0, 0, 3, 3, 1,
                         snapshot_bytes(3, 0, [col(live + tail)])),
    })

    # 4. a referenced segment file is MISSING: the whole newer generation
    # is rejected (partial loads could mix swaps), recovery falls back to
    # the predecessor, and the still-present WAL covers the difference
    first = rows_of(2)
    second = rows_of(2)
    gen1 = manifest_bytes(1, 2, 2, 0, [mcol([(1, 2, bits)])])
    gen2 = manifest_bytes(2, 4, 3, 0, [mcol([(1, 2, bits), (2, 2, bits)])])
    wal = (wal_record(2, "docs", d, second[:d])
           + wal_record(3, "docs", d, second[d:]))
    cases.append({
        "name": "missing-referenced-segment",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): gen1.hex(),
                  manifest_file(2): gen2.hex(),
                  segment_file("docs", 1): seg(1, first).hex(),
                  # segment 2 intentionally absent
                  "wal/docs.wal": wal.hex()},
        "expect": expect(2, 2, 0, 1, 4, 4, 1,
                         snapshot_bytes(4, 0, [col(first + second)])),
    })

    # 5. a referenced segment file is CORRUPT (one flipped byte fails its
    # CRC): same whole-generation rejection and fallback as case 4
    broken = bytearray(seg(2, second))
    broken[25] ^= 0x10
    cases.append({
        "name": "corrupt-referenced-segment",
        "bits": bits,
        "metric": "ip",
        "files": {manifest_file(1): gen1.hex(),
                  manifest_file(2): gen2.hex(),
                  segment_file("docs", 1): seg(1, first).hex(),
                  segment_file("docs", 2): bytes(broken).hex(),
                  "wal/docs.wal": wal.hex()},
        "expect": expect(2, 2, 0, 1, 4, 4, 1,
                         snapshot_bytes(4, 0, [col(first + second)])),
    })

    return {"kernel": "segment_recovery", "cases": cases}


# ------------------------------------------------------------ cluster merge

def gen_cluster():
    """Pin the cluster scatter-gather merge pipeline (`cluster::merge`).

    Rows are partitioned round-robin by global id (shard = gid % S,
    local = gid // S). Phase 1 takes each shard's local top-`take` by
    estimated score — the same (score desc, index asc) order as
    `index::top_indices` — phase 1.5 maps local ids back to global and
    selects the global top-`take` by (est desc, gid asc), and phase 2
    merges exact scores by (exact desc, gid asc) truncated to k. The
    committed expectations pin every stage so the Rust router and this
    mirror can never drift apart silently.

    Scores are distinct f32s with a minimum pairwise gap, so the order
    is unambiguous (no ties for the index tiebreak to hide in).
    """
    rng = random.Random(0xC7A5)
    n, n_shards, k, rf = 37, 3, 4, 2
    take = min(max(rf, 1) * k, n)  # 8 < every shard's 12-13 rows: the
    # local and global truncations are both actually exercised

    def distinct_scores():
        while True:
            xs = rand_f32_list(rng, n, scale=1.0)
            srt = sorted(xs)
            if all(b - a > 1e-3 for a, b in zip(srt, srt[1:])):
                return xs

    est = distinct_scores()
    exact = distinct_scores()

    def shard_rows(s):
        return n // n_shards + (1 if s < n % n_shards else 0)

    per_shard = []
    for s in range(n_shards):
        local_est = [est[l * n_shards + s] for l in range(shard_rows(s))]
        order = sorted(range(len(local_est)),
                       key=lambda i: (-local_est[i], i))[:take]
        per_shard.append([{"id": i, "score": local_est[i]} for i in order])

    cands = []
    for s, hits in enumerate(per_shard):
        for h in hits:
            cands.append((h["score"], h["id"] * n_shards + s))
    cands.sort(key=lambda t: (-t[0], t[1]))
    selected_gids = [g for _, g in cands[:take]]

    merged_pairs = sorted(((exact[g], g) for g in selected_gids),
                          key=lambda t: (-t[0], t[1]))[:k]
    merged = [{"id": g, "score": sc} for sc, g in merged_pairs]

    return {
        "kernel": "cluster_merge",
        "n": n,
        "n_shards": n_shards,
        "k": k,
        "rerank_factor": rf,
        "take": take,
        "est": est,
        "exact": exact,
        "per_shard_candidates": per_shard,
        "selected_gids": selected_gids,
        "merged": merged,
    }


# ------------------------------------------------------ metrics exposition

# Mirror of `obs::LATENCY_BUCKETS_US`: the shared log-spaced 1-2-5 µs
# bucket ladder every duration histogram uses (last slot at render time
# is the implicit +Inf overflow).
METRIC_BUCKETS_US = [
    1, 2, 5, 10, 20, 50, 100, 200, 500, 1_000, 2_000, 5_000, 10_000,
    20_000, 50_000, 100_000, 200_000, 500_000, 1_000_000, 2_000_000,
    5_000_000,
]


def metrics_bucketize(values_us):
    """Mirror of `obs::bucketize_us` / `Histogram::observe_us` placement:
    non-cumulative counts, first edge >= v wins (le is inclusive), final
    slot is the +Inf overflow."""
    counts = [0] * (len(METRIC_BUCKETS_US) + 1)
    for v in values_us:
        idx = next((i for i, e in enumerate(METRIC_BUCKETS_US) if v <= e),
                   len(METRIC_BUCKETS_US))
        counts[idx] += 1
    return counts


def metrics_render(families):
    """Mirror of `obs::Registry::render`: families sorted by (name,
    registration index), HELP/TYPE once per name (first registration's
    help and kind win), histograms rendered cumulative with a trailing
    +Inf bucket then `_sum`/`_count`. Every value is an integer, so the
    text is byte-deterministic — the property the fixture pins."""
    order = sorted(range(len(families)), key=lambda i: (families[i]["fname"], i))
    out = []
    last = None
    for i in order:
        f = families[i]
        if f["fname"] != last:
            out.append(f"# HELP {f['fname']} {f['help']}")
            out.append(f"# TYPE {f['fname']} {f['kind']}")
            last = f["fname"]

        def labels(extra=None):
            parts = [f'{k}="{v}"' for k, v in f.get("labels", [])]
            if extra is not None:
                parts.append(f'{extra[0]}="{extra[1]}"')
            return "{" + ",".join(parts) + "}" if parts else ""

        if f["kind"] == "histogram":
            counts = metrics_bucketize(f["observe_us"])
            cum = 0
            for edge, c in zip(METRIC_BUCKETS_US, counts):
                cum += c
                out.append(f"{f['fname']}_bucket{labels(('le', edge))} {cum}")
            cum += counts[-1]
            out.append(f"{f['fname']}_bucket{labels(('le', '+Inf'))} {cum}")
            out.append(f"{f['fname']}_sum{labels()} {sum(f['observe_us'])}")
            out.append(f"{f['fname']}_count{labels()} {len(f['observe_us'])}")
        else:
            out.append(f"{f['fname']}{labels()} {f['value']}")
    return "".join(line + "\n" for line in out)


def metrics_relabel(text, key, value):
    """Mirror of `obs::relabel_exposition`: inject `key="value"` as the
    FIRST label of every sample line; comment and empty lines pass
    through untouched."""
    out = []
    for line in text.split("\n")[:-1] if text.endswith("\n") else text.split("\n"):
        if not line or line.startswith("#"):
            out.append(line)
            continue
        sp = line.rfind(" ")
        if sp == -1:
            out.append(line)
            continue
        series, val = line[:sp], line[sp:]
        b = series.find("{")
        if b != -1:
            series = series[:b + 1] + f'{key}="{value}",' + series[b + 1:]
        else:
            series = series + "{" + f'{key}="{value}"' + "}"
        out.append(series + val)
    return "".join(line + "\n" for line in out)


def gen_metrics():
    """Registry-state -> rendered-exposition fixtures for the Rust
    `obs::Registry` (consumed by `rust/tests/golden.rs`, sanity-checked
    by `python/tests/test_obs.py`). Families carry declarative state
    (counter/gauge value, or the histogram's raw observations) so both
    sides construct the same registry and must render the same bytes.
    Cases cover the edge shapes the format hides bugs in: an empty
    registry, an empty histogram, all observations in one bucket,
    exact-edge placement (le is inclusive), +Inf overflow, zero-valued
    and negative samples, labeled samples sharing a family, and
    registration order disagreeing with name order."""
    cases = []

    # 1. empty registry: renders to the empty string, not "\n"
    cases.append({"name": "empty-registry", "families": []})

    # 2. counters + a negative gauge, registered out of name order (the
    # render must sort), including a zero-valued counter
    cases.append({"name": "counters-and-gauge", "families": [
        {"fname": "raana_tokens_generated_total", "kind": "counter",
         "help": "Tokens sampled by the batching server.", "value": 1234},
        {"fname": "raana_completions_total", "kind": "counter",
         "help": "Generations run to completion.", "value": 0},
        {"fname": "raana_queue_depth", "kind": "gauge",
         "help": "Requests admitted but not yet mapped onto a KV lane.",
         "value": -3},
    ]})

    # 3. labeled samples sharing one family name: HELP/TYPE once (first
    # registration wins), samples in registration order
    cases.append({"name": "labeled-family", "families": [
        {"fname": "raana_worker_up", "kind": "gauge",
         "help": "Per-worker liveness.", "labels": [["worker", "0"]],
         "value": 1},
        {"fname": "raana_worker_up", "kind": "gauge",
         "help": "IGNORED: only the first registration's help renders.",
         "labels": [["worker", "1"]], "value": 0},
    ]})

    # 4. empty histogram: all-zero cumulative buckets, sum 0, count 0
    cases.append({"name": "histogram-empty", "families": [
        {"fname": "raana_prefill_us", "kind": "histogram",
         "help": "Serve-level prefill, microseconds.", "observe_us": []},
    ]})

    # 5. every observation in a single bucket (11..=20 -> le="20")
    cases.append({"name": "histogram-single-bucket", "families": [
        {"fname": "raana_decode_step_us", "kind": "histogram",
         "help": "One batched decode step, microseconds.",
         "observe_us": [15, 12, 20, 11]},
    ]})

    # 6. edges and overflow: 0 and 1 land in le="1" (inclusive), each
    # exact edge lands in its own bucket, 5_000_001 overflows to +Inf
    cases.append({"name": "histogram-edges-and-inf", "families": [
        {"fname": "raana_queue_wait_us", "kind": "histogram",
         "help": "Admission-to-KV-lane wait, microseconds.",
         "observe_us": [0, 1, 2, 3, 5, 5_000_000, 5_000_001, 999_999_999]},
    ]})

    # 7. mixed kinds with interleaved names: pins the (name, registration
    # index) sort and the one-histogram-between-counters layout
    cases.append({"name": "mixed-sorted", "families": [
        {"fname": "raana_z_total", "kind": "counter", "help": "Last by name.",
         "value": 7},
        {"fname": "raana_m_us", "kind": "histogram", "help": "Middle by name.",
         "observe_us": [4, 40, 400]},
        {"fname": "raana_a_total", "kind": "counter", "help": "First by name.",
         "value": 9},
    ]})

    for c in cases:
        c["rendered"] = metrics_render(c["families"])

    # fleet aggregation: the router injects worker="<i>" as the first
    # label of every sample line, comments untouched
    relabel_cases = []
    for key, value, src in (
        ("worker", "0", cases[5]["rendered"]),   # histogram with le labels
        ("worker", "17", cases[2]["rendered"]),  # already-labeled samples
        ("worker", "3", cases[1]["rendered"]),   # bare counters + gauge
    ):
        relabel_cases.append({
            "key": key,
            "value": value,
            "input": src,
            "output": metrics_relabel(src, key, value),
        })

    return {
        "kernel": "metrics_exposition",
        "buckets_us": METRIC_BUCKETS_US,
        "cases": cases,
        "relabel_cases": relabel_cases,
    }


# ----------------------------------------------------------------- harness

GENERATORS = {
    "fwht.json": gen_fwht,
    "decode_codes.json": gen_decode,
    "attend_cached.json": gen_attend,
    "kvq_attend.json": gen_kvq,
    "index_search.json": gen_index,
    "durability.json": gen_durability,
    "segments.json": gen_segments,
    "cluster_merge.json": gen_cluster,
    "metrics_exposition.json": gen_metrics,
}


def render(doc):
    return json.dumps(doc, sort_keys=True, separators=(",", ":")) + "\n"


def main(argv):
    check = "--check" in argv
    VECTOR_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for name, gen in GENERATORS.items():
        path = VECTOR_DIR / name
        text = render(gen())
        if check:
            committed = path.read_text() if path.exists() else None
            if committed != text:
                failures.append(name)
            else:
                print(f"ok: {name} matches regeneration")
        else:
            path.write_text(text)
            print(f"wrote {path} ({len(text)} bytes)")
    if failures:
        print(f"STALE golden vectors: {failures} — rerun gen_vectors.py", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
