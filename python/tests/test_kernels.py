"""Pallas kernels vs pure-jnp oracles (kernels/ref.py).

Hypothesis sweeps shapes (powers of 2 and odd sizes via the wrapper's block
shrinking), bit-widths, and value scales; fixed-seed numpy feeds the data so
failures reproduce.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul_pallas, linear_matmul
from compile.kernels.hadamard import fwht_pallas, rht_pallas
from compile.kernels.qmatmul import qmatmul_pallas
from compile.kernels.rabitq import rabitq_quantize_pallas

import jax

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------- matmul

@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 2, 3, 8, 64, 100, 128]),
    k=st.sampled_from([1, 4, 32, 96, 128]),
    n=st.sampled_from([1, 2, 16, 100, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = _rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    got = matmul_pallas(x, w)
    want = ref.ref_matmul(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_scale_invariance():
    rng = _rng(7)
    x = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32)) * 1e3
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32)) * 1e-3
    np.testing.assert_allclose(matmul_pallas(x, w), ref.ref_matmul(x, w),
                               rtol=1e-3, atol=1e-3)


def test_linear_matmul_grad_matches_jnp():
    """custom_vjp backward must equal the jnp matmul gradient."""
    rng = _rng(3)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))

    def f_pallas(x, w):
        return jnp.sum(jnp.sin(linear_matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.sin(jnp.matmul(x, w)))

    gx1, gw1 = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx1, gx2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw1, gw2, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------- FWHT

@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 2, 5, 8, 64, 129]),
    logd=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_ref(rows, logd, seed):
    d = 1 << logd
    rng = _rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    np.testing.assert_allclose(fwht_pallas(x), ref.ref_fwht(x),
                               rtol=1e-4, atol=1e-4)


def test_fwht_is_orthonormal_involution():
    """H/sqrt(d) is orthonormal and an involution: FWHT(FWHT(x)) == x."""
    rng = _rng(11)
    x = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
    y = fwht_pallas(fwht_pallas(x))
    np.testing.assert_allclose(y, x, rtol=1e-4, atol=1e-4)


def test_fwht_preserves_norm():
    rng = _rng(13)
    x = jnp.asarray(rng.normal(size=(8, 512)).astype(np.float32))
    got = jnp.linalg.norm(fwht_pallas(x), axis=1)
    want = jnp.linalg.norm(x, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_fwht_matches_explicit_hadamard_matrix():
    d = 16
    H = np.array([[1.0]])
    while H.shape[0] < d:
        H = np.block([[H, H], [H, -H]])
    rng = _rng(5)
    x = rng.normal(size=(3, d)).astype(np.float32)
    want = (x @ H) / np.sqrt(d)
    np.testing.assert_allclose(fwht_pallas(jnp.asarray(x)), want,
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    logd=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_rht_matches_ref_and_inverts(logd, seed):
    d = 1 << logd
    rng = _rng(seed)
    x = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    sign = jnp.asarray(rng.choice([-1.0, 1.0], size=d).astype(np.float32))
    y = rht_pallas(x, sign)
    np.testing.assert_allclose(y, ref.ref_rht(x, sign), rtol=1e-4, atol=1e-4)
    # inverse: x = sign * FWHT(y)
    back = ref.ref_fwht(y) * sign
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------- RaBitQ

@settings(**SETTINGS)
@given(
    d=st.sampled_from([8, 64, 128, 256]),
    c=st.sampled_from([1, 2, 16, 100, 128]),
    bits=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_rabitq_matches_ref(d, c, bits, seed):
    rng = _rng(seed)
    v = jnp.asarray(rng.normal(size=(d, c)).astype(np.float32))
    c1, r1 = rabitq_quantize_pallas(v, bits=bits)
    c2, r2 = ref.ref_rabitq_quantize(v, bits)
    np.testing.assert_allclose(c1, c2)
    np.testing.assert_allclose(r1, r2, rtol=1e-4, atol=1e-6)


def test_rabitq_codes_in_range():
    rng = _rng(17)
    v = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)) * 10
    for bits in (1, 2, 4, 8):
        codes, _ = rabitq_quantize_pallas(v, bits=bits)
        assert float(codes.min()) >= 0.0
        assert float(codes.max()) <= 2.0**bits - 1.0
        assert np.all(codes == np.round(codes))


def test_rabitq_zero_column():
    v = jnp.zeros((32, 4), jnp.float32)
    codes, r = rabitq_quantize_pallas(v, bits=3)
    # all-zero column quantizes to the grid center with r = 0
    np.testing.assert_allclose(r, 0.0)
    y = ref.ref_qmatmul(jnp.ones((2, 32)), codes, r, 3)
    np.testing.assert_allclose(y, 0.0)


@settings(**SETTINGS)
@given(
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_rabitq_reconstruction_error_shrinks_with_bits(bits, seed):
    """Relative reconstruction error decays ~2^-b (Assumption 4.1)."""
    rng = _rng(seed)
    d = 256
    v = jnp.asarray(rng.normal(size=(d, 8)).astype(np.float32))
    codes, r = rabitq_quantize_pallas(v, bits=bits)
    recon = ref.ref_dequantize(codes, r, bits)
    rel = float(jnp.linalg.norm(recon - v) / jnp.linalg.norm(v))
    # generous constant; the point is the 2^-b scaling law
    assert rel < 4.0 * 2.0**-bits, f"bits={bits} rel={rel}"


# -------------------------------------------------------------------- qmatmul

@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 8, 100, 128]),
    d=st.sampled_from([16, 64, 256]),
    c=st.sampled_from([1, 16, 128]),
    bits=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(n, d, c, bits, seed):
    rng = _rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(d, c)).astype(np.float32))
    codes, r = ref.ref_rabitq_quantize(v, bits)
    got = qmatmul_pallas(x, codes, r, bits=bits)
    want = ref.ref_qmatmul(x, codes, r, bits)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_qmatmul_equals_dequantized_matmul():
    """Alg. 3 fused form == X @ dequantize(codes, r)."""
    rng = _rng(23)
    x = jnp.asarray(rng.normal(size=(32, 128)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    for bits in (2, 4):
        codes, r = ref.ref_rabitq_quantize(v, bits)
        fused = qmatmul_pallas(x, codes, r, bits=bits)
        unfused = x @ ref.ref_dequantize(codes, r, bits)
        np.testing.assert_allclose(fused, unfused, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(3, 8))
def test_qmatmul_error_bound_eq11(seed, bits):
    """Paper eq. 11: |<x,w> - est| < c_err/(sqrt(d) 2^b) ||x|| ||w||.

    Our grid uses max-abs scaling rather than the paper's normalized codebook
    so we check the same functional form with a relaxed constant.
    """
    rng = _rng(seed)
    d = 512
    x = jnp.asarray(rng.normal(size=(16, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(d, 16)).astype(np.float32))
    codes, r = ref.ref_rabitq_quantize(v, bits)
    est = np.asarray(qmatmul_pallas(x, codes, r, bits=bits))
    exact = np.asarray(x @ v)
    bound = (
        3.0 * 5.75 / (np.sqrt(d) * 2.0**bits)
        * np.linalg.norm(np.asarray(x), axis=1, keepdims=True)
        * np.linalg.norm(np.asarray(v), axis=0, keepdims=True)
    )
    frac_ok = np.mean(np.abs(est - exact) <= bound)
    assert frac_ok >= 0.98, f"bound violated on {1 - frac_ok:.2%}"
