"""Observability fixture mirror (numpy-only — runs where jax is absent).

The committed ``metrics_exposition.json`` pins the Rust registry's
Prometheus text rendering byte-for-byte (``rust/tests/golden.rs``
consumes it). This suite keeps the fixture itself honest from the
Python side, so a bad generator cannot pin a bad renderer:

1. bucket placement must agree with an independent numpy formulation
   (``np.digitize`` with right-closed intervals) — the generator's
   linear scan and the kernel's ``position(v <= edge)`` encode the same
   inclusive-``le`` semantics;
2. every rendered histogram must be internally consistent: cumulative
   buckets monotone, the ``+Inf`` bucket equal to ``_count``, ``_sum``
   equal to the sum of the raw observations;
3. the exposition grammar must hold line by line (HELP/TYPE once per
   family, families name-sorted, every sample value an integer);
4. the relabel cases must put the injected label FIRST on every sample
   line and change nothing else — the property that keeps the router's
   fleet aggregation a pure text rewrite.
"""

import json
import re

import numpy as np
import pytest

import gen_vectors as gv

DOC = json.loads((gv.VECTOR_DIR / "metrics_exposition.json").read_text())

SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})? (?P<value>-?\d+)$'
)


def parse_samples(text):
    """(name, labels-string, int value) triples of every sample line."""
    out = []
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        m = SAMPLE_RE.match(line)
        assert m, f"unparseable sample line: {line!r}"
        out.append((m["name"], m["labels"] or "", int(m["value"])))
    return out


def test_bucket_ladder_matches_rust_constant_shape():
    edges = DOC["buckets_us"]
    assert edges == sorted(edges) and len(set(edges)) == len(edges)
    assert edges[0] == 1 and edges[-1] == 5_000_000
    assert edges == gv.METRIC_BUCKETS_US


def test_bucketize_agrees_with_numpy_digitize():
    edges = np.asarray(DOC["buckets_us"], dtype=np.uint64)
    rng = np.random.default_rng(0x0B5)
    vals = np.concatenate([
        rng.integers(0, 10_000_000, size=500, dtype=np.uint64),
        edges,          # every exact edge
        edges + 1,      # just past every edge
        np.asarray([0], dtype=np.uint64),
    ])
    counts = np.asarray(gv.metrics_bucketize(vals.tolist()))
    # independent formulation: right-closed interval index per value
    idx = np.digitize(vals, edges, right=True)
    want = np.bincount(idx, minlength=len(edges) + 1)
    np.testing.assert_array_equal(counts, want)


@pytest.mark.parametrize("case", DOC["cases"], ids=lambda c: c["name"])
def test_rendered_histograms_are_consistent(case):
    text = case["rendered"]
    for fam in case["families"]:
        if fam["kind"] != "histogram":
            continue
        name = fam["fname"]
        buckets = []
        for line in text.splitlines():
            m = re.match(rf'^{name}_bucket{{.*le="([^"]+)"}} (\d+)$', line)
            if m:
                buckets.append((m[1], int(m[2])))
        assert [b[0] for b in buckets] == [str(e) for e in DOC["buckets_us"]] + ["+Inf"]
        cum = [b[1] for b in buckets]
        assert cum == sorted(cum), "cumulative buckets must be monotone"
        samples = dict((n, v) for n, _, v in parse_samples(text))
        assert cum[-1] == len(fam["observe_us"]) == samples[f"{name}_count"]
        assert samples[f"{name}_sum"] == sum(fam["observe_us"])


@pytest.mark.parametrize("case", DOC["cases"], ids=lambda c: c["name"])
def test_exposition_grammar_and_family_order(case):
    text = case["rendered"]
    if not case["families"]:
        assert text == ""
        return
    assert text.endswith("\n") and "\n\n" not in text
    helped, typed, family_order = [], [], []
    for line in text.splitlines():
        if line.startswith("# HELP "):
            helped.append(line.split(" ", 3)[2])
        elif line.startswith("# TYPE "):
            name = line.split(" ", 3)[2]
            typed.append(name)
            family_order.append(name)
        else:
            assert SAMPLE_RE.match(line), f"bad sample line {line!r}"
    assert helped == typed, "HELP and TYPE must pair up in order"
    assert len(set(helped)) == len(helped), "HELP/TYPE must appear once per family"
    assert family_order == sorted(family_order), "families must render name-sorted"
    # counter/gauge values round-trip exactly
    samples = parse_samples(text)
    for fam in case["families"]:
        if fam["kind"] in ("counter", "gauge"):
            labels = ",".join(f'{k}="{v}"' for k, v in fam.get("labels", []))
            assert (fam["fname"], labels, fam["value"]) in samples


@pytest.mark.parametrize("rc", DOC["relabel_cases"],
                         ids=lambda rc: f'{rc["key"]}={rc["value"]}')
def test_relabel_injects_first_label_and_nothing_else(rc):
    key, value = rc["key"], rc["value"]
    in_lines = rc["input"].splitlines()
    out_lines = rc["output"].splitlines()
    assert len(in_lines) == len(out_lines)
    tag = f'{key}="{value}"'
    for src, dst in zip(in_lines, out_lines):
        if not src or src.startswith("#"):
            assert dst == src, "comment/empty lines must pass through"
            continue
        m = SAMPLE_RE.match(dst)
        assert m, f"relabeled line unparseable: {dst!r}"
        assert m["labels"].split(",")[0] == tag, "injected label must come first"
        # removing the injected label restores the source line exactly
        restored = dst.replace(tag + ",", "", 1) if tag + "," in dst \
            else dst.replace("{" + tag + "}", "", 1)
        assert restored == src
    # the mirror reproduces the committed output
    assert gv.metrics_relabel(rc["input"], key, value) == rc["output"]
