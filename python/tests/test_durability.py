"""Durability mirror suite (numpy-only — runs where rustc is absent).

The crash-safety layer (`rust/src/index/{wal,segment,durability}.rs`)
is pinned cross-language through the committed byte-level fixtures in
``rust/tests/vectors/durability.json``. This suite is the Python half of
that wall: an independent reimplementation of the WAL record format, the
RQSG segment / RQMF manifest formats, and the recovery state machine
(newest usable manifest generation → load + validate every referenced
segment → stop-at-first-corruption WAL parse → seq-merged replay), run
against the same fixture directories the Rust consumer recovers.

Three jobs:

1. **fixture re-derivation** — every committed case's recovery outcome
   (report counters, next_seq, and the canonical re-encoded snapshot) is
   recomputed from the raw directory bytes through this mirror, so the
   generator cannot pin a state it merely asserted;
2. **fault-injection properties, mirrored** — truncating a WAL at every
   byte recovers exactly the whole-record prefix, any single corrupted
   byte in a record ends the replayable prefix before it, and any
   corrupted or truncated segment or manifest is rejected outright
   (whole-body CRC);
3. **the tentpole property in numpy** — recovery from a sealed
   generation + a WAL torn at an arbitrary byte equals a fresh build of
   the durable add prefix, byte-for-byte through the canonical RQSN
   encoding (which is no longer written to disk but remains the logical
   equality yardstick).

The segment-specific walls (scatter, stale-width requantize, orphan and
missing/corrupt referenced segments) live in ``test_segments.py`` and
reuse this module's mirror.
"""

import json
import random
import struct
import zlib

import numpy as np
import pytest

import gen_vectors as gv

VEC = gv.VECTOR_DIR
D, BITS = 16, 6


# ------------------------------------------------------- WAL format mirror

def parse_payload(p):
    """Mirror of `wal::decode_payload`: None on any structural violation."""
    if len(p) < 11 or p[0] != 1:
        return None
    seq, = struct.unpack_from("<Q", p, 1)
    name_len, = struct.unpack_from("<H", p, 9)
    off = 11
    if len(p) < off + name_len + 8:
        return None
    try:
        name = p[off:off + name_len].decode()
    except UnicodeDecodeError:
        return None
    off += name_len
    dim, nrows = struct.unpack_from("<II", p, off)
    off += 8
    if dim == 0 or nrows == 0 or len(p) != off + dim * nrows * 4:
        return None
    rows = [float(x) for x in np.frombuffer(p[off:], dtype="<f4")]
    return {"seq": seq, "name": name, "dim": dim, "rows": rows}


def parse_wal(data):
    """Mirror of `wal::decode_records`: the replayable whole-record
    prefix plus how it ended ('clean' / 'torn' / 'bad-checksum' /
    'malformed'). Stop-at-first-corruption, never an exception."""
    recs = []
    off = 0
    n = len(data)
    while off < n:
        if n - off < 8:
            return recs, "torn"
        ln, crc = struct.unpack_from("<II", data, off)
        if n - off - 8 < ln:
            return recs, "torn"
        payload = data[off + 8:off + 8 + ln]
        if zlib.crc32(payload) != crc:
            return recs, "bad-checksum"
        rec = parse_payload(payload)
        if rec is None:
            return recs, "malformed"
        recs.append(rec)
        off += 8 + ln
    return recs, "clean"


# --------------------------------------- segment / manifest format mirrors

def unpack_lsb_first(data, bits, n):
    """Inverse of `gen_vectors.pack_lsb_first` (LSB-first bit packing)."""
    val = int.from_bytes(bytes(data), "little")
    mask = (1 << bits) - 1
    return [(val >> (i * bits)) & mask for i in range(n)]


def f32_list(buf):
    return [float(x) for x in np.frombuffer(buf, dtype="<f4")]


def parse_segment(data):
    """Mirror of `segment::decode_segment`: the decoded file, or None
    when the CRC, magic, version, or structure is off."""
    if len(data) < 36:
        return None
    body, tail = data[:-4], data[-4:]
    if zlib.crc32(body) != struct.unpack("<I", tail)[0]:
        return None
    if body[:4] != b"RQSG" or struct.unpack_from("<I", body, 4)[0] != 1:
        return None
    try:
        off = 8
        name_len, = struct.unpack_from("<H", body, off)
        off += 2
        name = body[off:off + name_len].decode()
        off += name_len
        seg_id, = struct.unpack_from("<Q", body, off)
        off += 8
        d, = struct.unpack_from("<I", body, off)
        bits, metric = body[off + 4], body[off + 5]
        off += 6
        if d == 0 or not 1 <= bits <= 8 or metric > 1:
            return None
        nrows, codes_len = struct.unpack_from("<II", body, off)
        off += 8
        if codes_len != (nrows * d * bits + 7) // 8:
            return None
        codes = unpack_lsb_first(body[off:off + codes_len], bits, nrows * d)
        off += codes_len
        r = f32_list(body[off:off + 4 * nrows])
        off += 4 * nrows
        exact = f32_list(body[off:off + 4 * nrows * d])
        off += 4 * nrows * d
        if off != len(body) or len(r) != nrows or len(exact) != nrows * d:
            return None
    except (struct.error, IndexError, UnicodeDecodeError, ValueError):
        return None
    return {"name": name, "id": seg_id, "d": d, "bits": bits,
            "metric": metric, "codes": codes, "r": r, "exact": exact}


def parse_manifest(data):
    """Mirror of `segment::decode_manifest`: the decoded store manifest,
    or None when the CRC, magic, version, ordering, or any segment
    reference is off."""
    if len(data) < 48:
        return None
    body, tail = data[:-4], data[-4:]
    if zlib.crc32(body) != struct.unpack("<I", tail)[0]:
        return None
    if body[:4] != b"RQMF" or struct.unpack_from("<I", body, 4)[0] != 1:
        return None
    try:
        gen, next_seq, next_seg_id, rows_at_solve = \
            struct.unpack_from("<QQQQ", body, 8)
        ncols, = struct.unpack_from("<I", body, 40)
        off = 44
        cols = []
        prev_name = None
        for _ in range(ncols):
            name_len, = struct.unpack_from("<H", body, off)
            off += 2
            name = body[off:off + name_len].decode()
            off += name_len
            if prev_name is not None and prev_name >= name:
                return None
            prev_name = name
            d, = struct.unpack_from("<I", body, off)
            bits, metric = body[off + 4], body[off + 5]
            off += 6
            if d == 0 or not 1 <= bits <= 8 or metric > 1:
                return None
            d_hat, = struct.unpack_from("<I", body, off)
            off += 4
            if d_hat == 0 or d_hat > d:
                return None
            signs1 = f32_list(body[off:off + 4 * d_hat])
            off += 4 * d_hat
            s2len, = struct.unpack_from("<I", body, off)
            off += 4
            if s2len not in (0, d_hat):
                return None
            signs2 = f32_list(body[off:off + 4 * s2len])
            off += 4 * s2len
            nsegs, = struct.unpack_from("<I", body, off)
            off += 4
            segments = []
            for _ in range(nsegs):
                sid, srows = struct.unpack_from("<QI", body, off)
                sbits = body[off + 12]
                off += 13
                if srows == 0 or not 1 <= sbits <= 8 or sid >= next_seg_id:
                    return None
                segments.append((sid, srows, sbits))
            cols.append({"name": name, "d": d, "bits": bits,
                         "metric": metric, "signs1": signs1,
                         "signs2": signs2, "segments": segments})
    except (struct.error, IndexError, UnicodeDecodeError, ValueError):
        return None
    if off != len(body):
        return None
    return {"gen": gen, "next_seq": next_seq, "next_seg_id": next_seg_id,
            "rows_at_solve": rows_at_solve, "collections": cols}


def encode_state(state):
    """Canonical re-encoding of a recovered state — byte-identical to
    Rust's `encode_snapshot(store, next_seq)` by construction (which
    flattens and repacks codes globally regardless of how the rows were
    split between segments and the head)."""
    cols = []
    for name, c in state["collections"].items():
        cols.append({"name": name, "d": c["d"], "bits": c["bits"],
                     "signs1": c["signs1"], "signs2": c["signs2"],
                     "codes": bytes(gv.pack_lsb_first(c["codes"], c["bits"])),
                     "r": c["r"], "exact": c["exact"]})
    return gv.snapshot_bytes(state["next_seq"], state["rows_at_solve"], cols)


# --------------------------------------------------- recovery state machine

def manifest_gen(name):
    """Mirror of `segment::parse_manifest_gen`."""
    if not (name.startswith("manifest-") and name.endswith(".mf")):
        return None
    body = name[len("manifest-"):-len(".mf")]
    if len(body) != 20 or not body.isdigit():
        return None
    return int(body)


def load_generation(files, gen):
    """Mirror of `durability::load_manifest_generation`: decode the
    manifest at `gen`, then load and validate every referenced segment.
    ANY failure — corrupt manifest, missing file, corrupt segment, or a
    header that disagrees with its manifest entry — fails the whole
    generation (None). A per-segment width below the collection's means
    the file predates a rebalance: those rows are requantized from the
    segment's residual store. Returns (state, segment_count)."""
    m = parse_manifest(files.get(gv.manifest_file(gen), b""))
    if m is None or m["gen"] != gen:
        return None
    cols = {}
    nsegs = 0
    for mc in m["collections"]:
        col = {"d": mc["d"], "bits": mc["bits"], "metric": mc["metric"],
               "signs1": mc["signs1"], "signs2": mc["signs2"],
               "codes": [], "r": [], "exact": []}
        for sid, srows, sbits in mc["segments"]:
            path = gv.segment_file(mc["name"], sid)
            if path not in files:
                return None
            seg = parse_segment(files[path])
            if seg is None:
                return None
            if (seg["name"] != mc["name"] or seg["id"] != sid
                    or seg["d"] != mc["d"] or seg["metric"] != mc["metric"]
                    or len(seg["r"]) != srows or seg["bits"] != sbits):
                return None
            if sbits != mc["bits"]:
                codes, rs = gv.index_quantize_rows(
                    seg["exact"], srows, mc["d"], mc["bits"],
                    mc["signs1"], mc["signs2"])
            else:
                codes, rs = seg["codes"], seg["r"]
            col["codes"].extend(codes)
            col["r"].extend(rs)
            col["exact"].extend(seg["exact"])
            nsegs += 1
        cols[mc["name"]] = col
    state = {"next_seq": m["next_seq"], "rows_at_solve": m["rows_at_solve"],
             "collections": cols}
    return state, nsegs


def recover(files):
    """Mirror of `durability::recover` over a dict of relative path →
    bytes: newest loadable manifest generation (failed ones counted and
    skipped), per-file stop-at-first-corruption WAL parse, seq-sorted
    merge, and a contiguous replay from the manifest's next_seq. Replay
    targets must already exist in the manifest (the fixture contract —
    fresh collections would need the Rust sign-sampling RNG).

    The Rust engine additionally RESEALS after a recovery that dropped,
    skipped, or rejected anything (seal + delete all WALs) before
    accepting new writes; that is post-recovery engine behavior, not
    part of the recovery function mirrored here — the recovered state
    and report this returns are unaffected by it."""
    report = {"snapshot_rows": 0, "replayed_rows": 0, "dropped_records": 0,
              "duplicate_records": 0, "corrupt_snapshots": 0, "segments": 0}
    gens = sorted((manifest_gen(n) for n in files
                   if manifest_gen(n) is not None), reverse=True)
    state = None
    for gen in gens:
        loaded = load_generation(files, gen)
        if loaded is not None:
            state, report["segments"] = loaded
            break
        report["corrupt_snapshots"] += 1
    if state is None:
        state = {"next_seq": 0, "rows_at_solve": 0, "collections": {}}
    report["snapshot_rows"] = sum(
        len(c["r"]) for c in state["collections"].values())
    records = []
    for name in sorted(files):
        if not (name.startswith("wal/") and name.endswith(".wal")):
            continue
        recs, tail = parse_wal(files[name])
        if tail != "clean":
            report["dropped_records"] += 1
        records.extend(recs)
    records.sort(key=lambda r: r["seq"])
    next_seq = state["next_seq"]
    for rec in records:
        if rec["seq"] < next_seq:
            report["duplicate_records"] += 1
            continue
        if rec["seq"] > next_seq:
            report["dropped_records"] += 1
            continue
        c = state["collections"][rec["name"]]
        n_new = len(rec["rows"]) // rec["dim"]
        codes, rs = gv.index_quantize_rows(
            rec["rows"], n_new, c["d"], c["bits"], c["signs1"], c["signs2"])
        c["codes"].extend(codes)
        c["r"].extend(rs)
        c["exact"].extend(rec["rows"])
        report["replayed_rows"] += n_new
        next_seq = rec["seq"] + 1
    state["next_seq"] = next_seq
    return state, report


# ----------------------------------------------------------------- fixtures

def durability_cases():
    return json.loads((VEC / "durability.json").read_text())["cases"]


def case_files(case):
    return {path: bytes.fromhex(h) for path, h in case["files"].items()}


@pytest.mark.parametrize("case", durability_cases(), ids=lambda c: c["name"])
def test_committed_cases_rederive_through_the_mirror(case):
    # the committed expectations must fall out of an independent recovery
    # run over the raw directory bytes — counters, next_seq, and the
    # canonical re-encoding all recomputed, nothing trusted
    state, report = recover(case_files(case))
    expect = case["expect"]
    for key in ("snapshot_rows", "replayed_rows", "dropped_records",
                "duplicate_records", "corrupt_snapshots"):
        assert report[key] == expect[key], f"{case['name']}: {key}"
    assert state["next_seq"] == expect["next_seq"]
    assert sum(len(c["r"]) for c in state["collections"].values()) \
        == expect["rows"]
    assert encode_state(state).hex() == expect["reencoded_snapshot"], \
        f"{case['name']}: canonical re-encoding diverged"


def test_fixture_covers_the_required_edge_cases():
    names = {c["name"] for c in durability_cases()}
    required = {"empty-wal", "manifest-only", "torn-mid-record-tail",
                "duplicate-replay", "checksum-mismatch",
                "corrupt-manifest-fallback", "interleaved-collections"}
    assert required <= names, f"missing durability cases: {required - names}"


# ----------------------------------------------- fault-injection properties

def _signs(rng, d):
    return [float(rng.choice((-1.0, 1.0))) for _ in range(d)]


def _mcol(name, d, bits, signs1, signs2, segments):
    return {"name": name, "d": d, "bits": bits,
            "signs1": signs1, "signs2": signs2, "segments": segments}


def _wal_of(rng, n_records):
    recs = []
    out = b""
    for seq in range(n_records):
        rows = gv.rand_f32_list(rng, (1 + seq % 2) * D, 1.5)
        recs.append((seq, rows))
        out += gv.wal_record(seq, "docs", D, rows)
    return recs, out


def test_wal_truncation_at_every_byte_keeps_the_whole_record_prefix():
    rng = random.Random(0x7E42)
    recs, wal = _wal_of(rng, 3)
    boundaries = [0]
    off = 0
    for seq, rows in recs:
        off += len(gv.wal_record(seq, "docs", D, rows))
        boundaries.append(off)
    for cut in range(len(wal) + 1):
        got, tail = parse_wal(wal[:cut])
        want = max(i for i, b in enumerate(boundaries) if b <= cut)
        assert len(got) == want, f"cut={cut}"
        assert [g["seq"] for g in got] == [s for s, _ in recs[:want]]
        assert (tail == "clean") == (cut in boundaries), f"cut={cut}"


def test_any_corrupted_record_byte_ends_the_prefix_before_it():
    rng = random.Random(0x7E43)
    rows = gv.rand_f32_list(rng, 2 * D, 1.5)
    rec = gv.wal_record(5, "docs", D, rows)
    for byte in range(len(rec)):
        bad = bytearray(rec)
        bad[byte] ^= 0x10
        got, tail = parse_wal(bytes(bad))
        assert got == [] and tail != "clean", f"byte={byte}: {tail}"


def test_any_segment_or_manifest_corruption_or_truncation_is_rejected():
    rng = random.Random(0x7E44)
    signs1 = _signs(rng, D)
    rows = gv.rand_f32_list(rng, 3 * D, 1.5)
    seg = gv.segment_bytes("docs", 1, D, BITS, rows, signs1, [])
    man = gv.manifest_bytes(1, 3, 2, 0,
                            [_mcol("docs", D, BITS, signs1, [], [(1, 3, BITS)])])
    assert parse_segment(seg) is not None, "clean segment must decode"
    assert parse_manifest(man) is not None, "clean manifest must decode"
    for blob, parse in ((seg, parse_segment), (man, parse_manifest)):
        for byte in range(len(blob)):
            bad = bytearray(blob)
            bad[byte] ^= 0x04
            assert parse(bytes(bad)) is None, f"flip at {byte}"
        for cut in range(len(blob)):
            assert parse(blob[:cut]) is None, f"truncated to {cut}"


def test_segment_and_manifest_round_trip_through_the_mirror():
    # one sealed generation decodes back to exactly the state that wrote
    # it, and the canonical re-encoding round-trips bit-for-bit
    rng = random.Random(0x7E45)
    signs1 = _signs(rng, D)
    rows = gv.rand_f32_list(rng, 4 * D, 1.5)
    files = {
        gv.manifest_file(1): gv.manifest_bytes(
            1, 7, 2, 0, [_mcol("docs", D, BITS, signs1, [], [(1, 4, BITS)])]),
        gv.segment_file("docs", 1): gv.segment_bytes(
            "docs", 1, D, BITS, rows, signs1, []),
    }
    state, report = recover(files)
    assert state["next_seq"] == 7
    assert list(state["collections"]) == ["docs"]
    assert report["segments"] == 1 and report["corrupt_snapshots"] == 0
    fresh = gv.snapshot_bytes(
        7, 0, [gv.durability_collection("docs", D, BITS, signs1, [], rows)])
    assert encode_state(state) == fresh


def test_recovery_equals_fresh_build_at_every_wal_tear_point():
    # the tentpole property, mirrored: one sealed generation covering the
    # first add, WAL carrying adds 2..=5; tearing the WAL at ANY byte
    # must recover exactly the fresh build of the whole-record prefix,
    # byte-for-byte through the canonical encoding
    rng = random.Random(0x7E46)
    signs1 = _signs(rng, D)
    adds = [gv.rand_f32_list(rng, (1 + i % 3) * D, 1.5) for i in range(5)]
    sealed = {
        gv.manifest_file(1): gv.manifest_bytes(
            1, 1, 2, 0,
            [_mcol("docs", D, BITS, signs1, [],
                   [(1, len(adds[0]) // D, BITS)])]),
        gv.segment_file("docs", 1): gv.segment_bytes(
            "docs", 1, D, BITS, adds[0], signs1, []),
    }
    wal = b""
    boundaries = [0]
    for seq, rows in enumerate(adds[1:], start=1):
        wal += gv.wal_record(seq, "docs", D, rows)
        boundaries.append(len(wal))
    for cut in range(len(wal) + 1):
        state, report = recover({**sealed, "wal/docs.wal": wal[:cut]})
        durable = 1 + max(i for i, b in enumerate(boundaries) if b <= cut)
        fresh_rows = [v for rows in adds[:durable] for v in rows]
        fresh = gv.snapshot_bytes(durable, 0, [gv.durability_collection(
            "docs", D, BITS, signs1, [], fresh_rows)])
        assert encode_state(state) == fresh, f"cut={cut}"
        assert report["replayed_rows"] == sum(
            len(r) // D for r in adds[1:durable])
        assert report["dropped_records"] == (0 if cut in boundaries else 1)


def test_duplicate_and_gap_replay_semantics():
    rng = random.Random(0x7E47)
    signs1 = _signs(rng, D)
    sealed = gv.rand_f32_list(rng, 2 * D, 1.5)
    fresh_row = gv.rand_f32_list(rng, D, 1.5)
    beyond_gap = gv.rand_f32_list(rng, D, 1.5)
    files = {
        gv.manifest_file(1): gv.manifest_bytes(
            1, 2, 2, 0, [_mcol("docs", D, BITS, signs1, [], [(1, 2, BITS)])]),
        gv.segment_file("docs", 1): gv.segment_bytes(
            "docs", 1, D, BITS, sealed, signs1, []),
        "wal/docs.wal":
            (gv.wal_record(0, "docs", D, sealed[:D])     # sealed: duplicate
             + gv.wal_record(2, "docs", D, fresh_row)    # contiguous: replays
             + gv.wal_record(4, "docs", D, beyond_gap)),  # seq 3 missing: drops
    }
    state, report = recover(files)
    assert report == {"snapshot_rows": 2, "replayed_rows": 1,
                      "dropped_records": 1, "duplicate_records": 1,
                      "corrupt_snapshots": 0, "segments": 1}
    assert state["next_seq"] == 3
