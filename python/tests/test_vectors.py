"""Golden-vector suite (numpy-only — runs where jax is absent).

Three jobs:
1. the committed ``rust/tests/vectors/*.json`` must be byte-identical to a
   regeneration (stale vectors are a silent contract break);
2. the vectors must be internally consistent (FWHT involution/norm in f32,
   decoder reads agreeing with the unpacked values, attention weights
   summing to 1) — so a bad generator cannot pin a bad kernel;
3. the files must stay parseable by the minimal Rust JSON subset (objects,
   arrays, finite numbers — no NaN/Infinity literals).
"""

import json
import math
from pathlib import Path

import numpy as np
import pytest

import gen_vectors as gv

VEC = gv.VECTOR_DIR


@pytest.mark.parametrize("name", sorted(gv.GENERATORS))
def test_committed_vectors_match_regeneration(name):
    path = VEC / name
    assert path.exists(), f"{path} missing — run python/tests/gen_vectors.py"
    assert path.read_text() == gv.render(gv.GENERATORS[name]()), (
        f"{name} is stale — rerun python/tests/gen_vectors.py"
    )


def test_vectors_contain_only_finite_numbers():
    # the Rust parser (correctly) refuses NaN/Infinity; walk every number
    def walk(v):
        if isinstance(v, float):
            assert math.isfinite(v)
        elif isinstance(v, list):
            for x in v:
                walk(x)
        elif isinstance(v, dict):
            for x in v.values():
                walk(x)

    for name in gv.GENERATORS:
        walk(json.loads((VEC / name).read_text()))


def test_fwht_vectors_are_orthonormal_involution():
    doc = json.loads((VEC / "fwht.json").read_text())
    for case in doc["cases"]:
        inp = np.asarray(case["input"], dtype=np.float32)
        out = np.asarray(case["output"], dtype=np.float32)
        assert out.shape == inp.shape
        # orthonormal: norm preserved
        np.testing.assert_allclose(
            np.linalg.norm(out), np.linalg.norm(inp), rtol=1e-5
        )
        # involution: applying the mirror again returns the input
        back = np.asarray(gv.fwht_f32(out), dtype=np.float32)
        np.testing.assert_allclose(back, inp, rtol=1e-4, atol=1e-5)


def test_decode_vectors_reads_are_consistent():
    doc = json.loads((VEC / "decode_codes.json").read_text())
    assert len(doc["cases"]) == 16, "two cases per bit width 1..8 (base + tail)"
    for case in doc["cases"]:
        bits, values = case["bits"], case["values"]
        assert all(0 <= v < (1 << bits) for v in values)
        assert case["data"] == gv.pack_lsb_first(values, bits)
        assert len(case["data"]) == (len(values) * bits + 7) // 8
        # at least one width must end mid-byte (the unaligned-tail cases)
        for read in case["reads"]:
            s, n = read["start"], read["len"]
            assert read["expect"] == values[s:s + n]
    # every width that can end mid-byte must do so in at least one case
    # (width 8 is structurally byte-aligned)
    tail_widths = {c["bits"] for c in doc["cases"]
                   if (len(c["values"]) * c["bits"]) % 8 != 0}
    assert tail_widths >= set(range(1, 8)), \
        f"widths missing a non-byte-aligned tail: {set(range(1, 8)) - tail_widths}"


def test_attend_vectors_match_independent_reference():
    doc = json.loads((VEC / "attend_cached.json").read_text())
    for case in doc["cases"]:
        heads, hd, ctx = case["heads"], case["head_dim"], case["ctx"]
        d = heads * hd
        q = np.asarray(case["q"], dtype=np.float64)
        k = np.asarray(case["k"], dtype=np.float64).reshape(ctx, d)
        v = np.asarray(case["v"], dtype=np.float64).reshape(ctx, d)
        out = np.asarray(case["out"], dtype=np.float64)
        # independent formulation: softmax via scipy-free logsumexp trick
        for h in range(heads):
            sl = slice(h * hd, (h + 1) * hd)
            logits = (k[:, sl] @ q[sl]) / np.sqrt(hd)
            w = np.exp(logits - logits.max())
            w /= w.sum()
            assert abs(w.sum() - 1.0) < 1e-12
            np.testing.assert_allclose(out[sl], w @ v[:, sl], rtol=1e-10, atol=1e-12)


def test_generator_check_mode_detects_staleness(tmp_path, monkeypatch):
    # point the generator at a scratch dir: --check must fail before files
    # exist, pass after generation, and fail after tampering
    monkeypatch.setattr(gv, "VECTOR_DIR", tmp_path)
    assert gv.main(["--check"]) == 1
    assert gv.main([]) == 0
    assert gv.main(["--check"]) == 0
    victim = tmp_path / "fwht.json"
    victim.write_text(victim.read_text().replace("cases", "cases_x", 1))
    assert gv.main(["--check"]) == 1
