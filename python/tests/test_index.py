"""Vector-index mirror suite (numpy-only — runs where jax is absent).

The Rust retrieval subsystem (`rust/src/index/`: full-row practical-RHT
rotation → MaxAbs RaBitQ quantization → packed-code estimated scan →
exact f32 rerank) has no rustc in some containers, so its *logic* is
validated here through the strict-f32 Python mirror in ``gen_vectors.py``
— the same functions that emit the ``index_search.json`` golden vectors
the Rust side is pinned against. Three jobs:

1. mirror self-checks: the scan reference agrees with the per-row
   Algorithm-3 estimator, and estimate error decays ~2^-bits;
2. the subsystem's property contract, mirrored: recall@k against the
   brute-force baseline is **non-decreasing along the 2 → 4 → 8-bit
   ladder** (and clears 0.95 at 8 bits with rerank_factor 4), a wider
   rerank pool never hurts (a deterministic superset property), and
   add → query of the identical vector ranks itself first at >= 4 bits
   after the exact rerank;
3. the committed golden vectors are internally consistent (codes
   regenerate from the committed rows, the top-k follows the committed
   scores), so a bad generator cannot pin a bad kernel.
"""

import json

import numpy as np
import pytest

import gen_vectors as gv

VEC = gv.VECTOR_DIR


def _mk_rng(seed):
    return np.random.default_rng(seed)


def _signs(rng, d):
    d_hat = gv.floor_pow2(d)
    signs1 = [float(s) for s in rng.choice((-1.0, 1.0), size=d_hat)]
    signs2 = ([] if d_hat == d
              else [float(s) for s in rng.choice((-1.0, 1.0), size=d_hat)])
    return signs1, signs2


def _unit_rows(rng, n, d):
    """n L2-normalized f32 rows, flat — the cosine store's residual
    content (`index::Collection` normalizes at the door)."""
    rows = []
    for _ in range(n):
        v = np.asarray([gv.f32(x) for x in rng.normal(size=d)], dtype=np.float32)
        nv = np.linalg.norm(v)
        if nv > 0:
            v = (v / np.float32(nv)).astype(np.float32)
        rows.extend(float(x) for x in v)
    return rows


def _two_phase(rows, q, n, d, bits, signs1, signs2, k, rerank_factor):
    """Mirror of `Collection::query`: estimated scan over codes, exact
    rerank of the top rerank_factor*k candidates. Returns the top-k ids."""
    codes, rs = gv.index_quantize_rows(rows, n, d, bits, signs1, signs2)
    est = gv.index_scan_ref(q, codes, rs, n, d, bits, signs1, signs2)
    cand = gv.index_top_k(est, min(rerank_factor * k, n))
    exact = gv.index_exact_scores(q, rows, n, d)
    return sorted(cand, key=lambda i: (-exact[i], i))[:k]


def _recall(rows, queries, n, d, bits, signs1, signs2, k, rerank_factor):
    hits = 0
    for q in queries:
        got = _two_phase(rows, q, n, d, bits, signs1, signs2, k, rerank_factor)
        want = set(gv.index_top_k(gv.index_exact_scores(q, rows, n, d), k))
        hits += len(want.intersection(got))
    return hits / (len(queries) * k)


# ------------------------------------------------------------ mirror checks

@pytest.mark.parametrize("d,bits", [(16, 8), (24, 4), (20, 5), (12, 3)])
def test_scan_ref_matches_per_row_estimator(d, bits):
    """The vectorized scan reference must agree with the scalar
    Algorithm-3 estimate computed row by row."""
    rng = _mk_rng(100 + d + bits)
    n = 7
    signs1, signs2 = _signs(rng, d)
    rows = [gv.f32(x) for x in rng.uniform(-1.5, 1.5, size=n * d)]
    q = [gv.f32(x) for x in rng.uniform(-1.5, 1.5, size=d)]
    codes, rs = gv.index_quantize_rows(rows, n, d, bits, signs1, signs2)
    scores = gv.index_scan_ref(q, codes, rs, n, d, bits, signs1, signs2)
    cb = (2 ** bits - 1) / 2.0
    q_rot = gv.practical_rht_f32(q, signs1, signs2).astype(np.float64)
    for i in range(n):
        ci = np.asarray(codes[i * d:(i + 1) * d], dtype=np.float64)
        want = rs[i] * (ci @ q_rot - cb * q_rot.sum())
        np.testing.assert_allclose(scores[i], want, rtol=1e-12, atol=1e-12)


def test_estimate_error_decays_with_bits():
    """|est - exact| on unit rows shrinks ~2^-b (the rotation makes the
    estimator's error bound apply)."""
    rng = _mk_rng(7)
    n, d = 64, 32
    signs1, signs2 = _signs(rng, d)
    rows = _unit_rows(rng, n, d)
    q = _unit_rows(rng, 1, d)
    exact = np.asarray(gv.index_exact_scores(q, rows, n, d))
    prev = np.inf
    for bits in (2, 4, 8):
        codes, rs = gv.index_quantize_rows(rows, n, d, bits, signs1, signs2)
        est = np.asarray(gv.index_scan_ref(q, codes, rs, n, d, bits, signs1, signs2))
        err = float(np.mean(np.abs(est - exact)))
        assert err < prev, f"bits={bits}: {err} !< {prev}"
        assert err < 4.0 * 2.0 ** -bits, f"bits={bits} err={err}"
        prev = err
    assert prev < 0.02, f"8-bit estimate error too large: {prev}"


# ------------------------------------------------------ property contract

def test_recall_nondecreasing_along_bit_ladder():
    """The satellite property, mirrored: recall@10 vs brute force is
    non-decreasing over 2 -> 4 -> 8 bits on a seeded fixture, and 8-bit
    with rerank_factor 4 clears the 0.95 acceptance bar."""
    rng = _mk_rng(777)
    n, d, k, rf = 256, 48, 10, 4
    signs1, signs2 = _signs(rng, d)
    rows = _unit_rows(rng, n, d)
    queries = [_unit_rows(rng, 1, d) for _ in range(16)]
    prev = -1.0
    for bits in (2, 4, 8):
        r = _recall(rows, queries, n, d, bits, signs1, signs2, k, rf)
        assert r >= prev, f"recall@{k} regressed: {r} < {prev} at {bits} bits"
        prev = r
    assert prev >= 0.95, f"8-bit recall@10 with rerank x4 must clear 0.95: {prev}"


def test_wider_rerank_never_hurts():
    """Deterministic superset property: the rerank_factor-4 candidate set
    contains the rerank_factor-1 set, so recall cannot drop."""
    rng = _mk_rng(991)
    n, d, k = 128, 32, 8
    signs1, signs2 = _signs(rng, d)
    rows = _unit_rows(rng, n, d)
    queries = [_unit_rows(rng, 1, d) for _ in range(8)]
    r1 = _recall(rows, queries, n, d, 2, signs1, signs2, k, 1)
    r4 = _recall(rows, queries, n, d, 2, signs1, signs2, k, 4)
    assert r4 >= r1, f"wider rerank must not hurt recall: {r4} < {r1}"


@pytest.mark.parametrize("bits", [4, 8])
def test_self_query_ranks_first_after_rerank(bits):
    """The satellite property, mirrored: querying a stored vector with
    itself ranks it first at >= 4 bits — the estimated scan keeps it in
    the candidate set, and the exact rerank pins cosine(self) = 1 at the
    top (maximal under the cosine metric, ties impossible for distinct
    unit rows)."""
    for seed in range(4):
        rng = _mk_rng(3000 + seed)
        n, d, k = 96, 24, 5
        signs1, signs2 = _signs(rng, d)
        rows = _unit_rows(rng, n, d)
        for probe in (0, n // 3, n - 1):
            q = rows[probe * d:(probe + 1) * d]
            got = _two_phase(rows, q, n, d, bits, signs1, signs2, k, 4)
            assert got[0] == probe, (
                f"bits={bits} seed={seed}: own vector must rank first, got {got}"
            )


# ------------------------------------------------- committed golden vectors

def test_index_vectors_are_internally_consistent():
    doc = json.loads((VEC / "index_search.json").read_text())
    assert len(doc["cases"]) >= 5
    nonpow2 = False
    tails = False
    for case in doc["cases"]:
        n, d, bits, k = case["n"], case["d"], case["bits"], case["k"]
        nonpow2 |= d & (d - 1) != 0
        tails |= (d * bits) % 8 != 0
        assert len(case["rows"]) == n * d
        assert len(case["codes"]) == n * d
        assert len(case["r"]) == n
        assert all(0 <= c <= 2 ** bits - 1 for c in case["codes"])
        assert len(case["signs1"]) == gv.floor_pow2(d)
        assert all(s in (-1.0, 1.0) for s in case["signs1"] + case["signs2"])
        # codes + rescales regenerate from the committed rows
        codes, rs = gv.index_quantize_rows(
            case["rows"], n, d, bits, case["signs1"], case["signs2"])
        assert codes == case["codes"]
        np.testing.assert_allclose(rs, case["r"], rtol=1e-6, atol=1e-9)
        # the packed bytes are exactly the packer's output
        assert case["data"] == gv.pack_lsb_first(case["codes"], bits)
        # scores and top-k regenerate and agree with the committed order
        est = gv.index_scan_ref(case["q"], case["codes"], case["r"],
                                n, d, bits, case["signs1"], case["signs2"])
        np.testing.assert_allclose(est, case["est_scores"], rtol=1e-12, atol=1e-12)
        assert gv.index_top_k(est, k) == case["topk"]
        exact = gv.index_exact_scores(case["q"], case["rows"], n, d)
        np.testing.assert_allclose(exact, case["exact_scores"],
                                   rtol=1e-12, atol=1e-12)
        # top-k order is protected by real gaps (the generator invariant)
        ranked = sorted(est, reverse=True)
        assert all(ranked[i] - ranked[i + 1] > 2e-3 for i in range(k))
    assert nonpow2, "vectors must cover a non-pow2 dimension"
    assert tails, "vectors must cover mid-byte row tails"
