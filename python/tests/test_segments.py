"""Segment-layout mirror suite (numpy-only — runs where rustc is
absent).

The segmented on-disk layout (`rust/src/index/segment.rs` + the
recovery side of `durability.rs`) is pinned cross-language through the
committed fixtures in ``rust/tests/vectors/segments.json``, consumed on
the Rust side by ``rust/tests/segments.rs``. This suite re-derives every
committed case through the recovery mirror in ``test_durability.py`` and
adds the segment-specific properties:

1. **scatter repack** — rows split across several sealed segments
   re-encode to the same canonical flattened RQSN bytes as one monolithic
   build (the fixture dimensions make per-segment code packing differ
   from the flattened packing, so this pins a real repack, not a
   concatenation);
2. **stale-width requantize** — a segment file sealed at one width under
   a manifest that has since narrowed the collection recovers
   bit-identical to a fresh encode at the new width;
3. **whole-generation rejection** — a missing or corrupt referenced
   segment fails its entire manifest generation, falling back to the
   kept predecessor, while valid orphan files are simply ignored.
"""

import json
import random

import pytest

import gen_vectors as gv
import test_durability as td

VEC = gv.VECTOR_DIR
D, BITS = 10, 5  # both RHT windows in play; 50-bit rows share bytes


# ----------------------------------------------------------------- fixtures

def segment_cases():
    return json.loads((VEC / "segments.json").read_text())["cases"]


@pytest.mark.parametrize("case", segment_cases(), ids=lambda c: c["name"])
def test_committed_cases_rederive_through_the_mirror(case):
    state, report = td.recover(td.case_files(case))
    expect = case["expect"]
    for key in ("snapshot_rows", "replayed_rows", "dropped_records",
                "corrupt_snapshots", "segments"):
        assert report[key] == expect[key], f"{case['name']}: {key}"
    assert state["next_seq"] == expect["next_seq"]
    assert sum(len(c["r"]) for c in state["collections"].values()) \
        == expect["rows"]
    assert td.encode_state(state).hex() == expect["reencoded_snapshot"], \
        f"{case['name']}: canonical re-encoding diverged"


def test_fixture_covers_the_required_edge_cases():
    names = {c["name"] for c in segment_cases()}
    required = {"multi-segment-scatter", "stale-width-requantize",
                "orphan-segment-ignored", "missing-referenced-segment",
                "corrupt-referenced-segment"}
    assert required <= names, f"missing segment cases: {required - names}"


# ------------------------------------------------------------- properties

def _env(seed):
    rng = random.Random(seed)
    d_hat = gv.floor_pow2(D)
    signs1 = [float(rng.choice((-1.0, 1.0))) for _ in range(d_hat)]
    signs2 = [float(rng.choice((-1.0, 1.0))) for _ in range(d_hat)]
    return rng, signs1, signs2


def _mcol(segments, signs1, signs2, bits=BITS):
    return {"name": "docs", "d": D, "bits": bits,
            "signs1": signs1, "signs2": signs2, "segments": segments}


def _seg(seg_id, rows, signs1, signs2, bits=BITS):
    return gv.segment_bytes("docs", seg_id, D, bits, rows, signs1, signs2)


def _fresh(rows, signs1, signs2, next_seq, bits=BITS):
    return gv.snapshot_bytes(next_seq, 0, [gv.durability_collection(
        "docs", D, bits, signs1, signs2, rows)])


def test_any_segment_split_reencodes_to_the_monolithic_build():
    # 6 rows split 1+5, 2+4, 3+3, … across two segments, plus a no-split
    # baseline: every split must recover to the SAME canonical bytes
    rng, signs1, signs2 = _env(0x5E01)
    rows = gv.rand_f32_list(rng, 6 * D, 1.5)
    fresh = _fresh(rows, signs1, signs2, 6)
    for cut_rows in range(7):
        a, b = rows[:cut_rows * D], rows[cut_rows * D:]
        segs = [(1, len(a) // D, BITS), (2, len(b) // D, BITS)]
        segs = [s for s in segs if s[1] > 0]
        files = {gv.manifest_file(1): gv.manifest_bytes(
            1, 6, 3, 0, [_mcol(segs, signs1, signs2)])}
        if a:
            files[gv.segment_file("docs", 1)] = _seg(1, a, signs1, signs2)
        if b:
            files[gv.segment_file("docs", 2)] = _seg(2, b, signs1, signs2)
        state, report = td.recover(files)
        assert report["segments"] == len(segs)
        assert td.encode_state(state) == fresh, f"split at row {cut_rows}"


def test_per_segment_packing_really_differs_from_the_flattened_packing():
    # the repack property above is only meaningful if concatenating the
    # per-segment code bytes would NOT equal the flattened packing — at
    # 50 bits per row a 1-row segment ends mid-byte, so it must differ
    rng, signs1, signs2 = _env(0x5E02)
    rows = gv.rand_f32_list(rng, 2 * D, 1.5)
    codes, _ = gv.index_quantize_rows(rows, 2, D, BITS, signs1, signs2)
    whole = bytes(gv.pack_lsb_first(codes, BITS))
    half_a = bytes(gv.pack_lsb_first(codes[:D], BITS))
    half_b = bytes(gv.pack_lsb_first(codes[D:], BITS))
    assert half_a + half_b != whole, \
        "fixture dims must force a real repack (rows share bytes)"


def test_stale_width_segment_requantizes_to_a_fresh_encode():
    # sealed at 5 bits, manifest narrowed to 3: recovery must requantize
    # from the residual store, equal to a fresh 3-bit build
    rng, signs1, signs2 = _env(0x5E03)
    rows = gv.rand_f32_list(rng, 3 * D, 1.5)
    files = {
        gv.manifest_file(1): gv.manifest_bytes(
            1, 3, 2, 0, [_mcol([(1, 3, BITS)], signs1, signs2, bits=3)]),
        gv.segment_file("docs", 1): _seg(1, rows, signs1, signs2, bits=BITS),
    }
    state, report = td.recover(files)
    assert report["corrupt_snapshots"] == 0 and report["segments"] == 1
    assert td.encode_state(state) == _fresh(rows, signs1, signs2, 3, bits=3)


def test_missing_or_corrupt_referenced_segment_fails_the_generation():
    rng, signs1, signs2 = _env(0x5E04)
    first = gv.rand_f32_list(rng, 2 * D, 1.5)
    second = gv.rand_f32_list(rng, D, 1.5)
    gen1 = gv.manifest_bytes(1, 2, 2, 0, [_mcol([(1, 2, BITS)], signs1, signs2)])
    gen2 = gv.manifest_bytes(2, 3, 3, 0,
                             [_mcol([(1, 2, BITS), (2, 1, BITS)],
                                    signs1, signs2)])
    base = {gv.manifest_file(1): gen1, gv.manifest_file(2): gen2,
            gv.segment_file("docs", 1): _seg(1, first, signs1, signs2),
            "wal/docs.wal": gv.wal_record(2, "docs", D, second)}
    corrupt = bytearray(_seg(2, second, signs1, signs2))
    corrupt[19] ^= 0x08
    for variant in (dict(base),
                    {**base, gv.segment_file("docs", 2): bytes(corrupt)}):
        state, report = td.recover(variant)
        assert report["corrupt_snapshots"] == 1, "gen 2 must be rejected"
        assert report["segments"] == 1 and report["replayed_rows"] == 1
        assert td.encode_state(state) == \
            _fresh(first + second, signs1, signs2, 3)


def test_valid_orphan_segments_are_ignored():
    # a crash between a segment write and its manifest commit leaves a
    # well-formed file no manifest references; recovery must not load it
    rng, signs1, signs2 = _env(0x5E05)
    live = gv.rand_f32_list(rng, 2 * D, 1.5)
    orphan = gv.rand_f32_list(rng, D, 1.5)
    files = {
        gv.manifest_file(1): gv.manifest_bytes(
            1, 2, 2, 0, [_mcol([(1, 2, BITS)], signs1, signs2)]),
        gv.segment_file("docs", 1): _seg(1, live, signs1, signs2),
        gv.segment_file("docs", 9): _seg(9, orphan, signs1, signs2),
    }
    state, report = td.recover(files)
    assert report["segments"] == 1 and report["corrupt_snapshots"] == 0
    assert td.encode_state(state) == _fresh(live, signs1, signs2, 2)


def test_header_disagreement_with_the_manifest_fails_the_generation():
    # a well-formed segment file whose row count disagrees with its
    # manifest entry (a swapped or stale file) must fail the generation
    rng, signs1, signs2 = _env(0x5E06)
    rows = gv.rand_f32_list(rng, 2 * D, 1.5)
    files = {
        gv.manifest_file(1): gv.manifest_bytes(
            1, 3, 2, 0, [_mcol([(1, 3, BITS)], signs1, signs2)]),
        gv.segment_file("docs", 1): _seg(1, rows, signs1, signs2),
    }
    state, report = td.recover(files)
    assert report["corrupt_snapshots"] == 1
    assert state["collections"] == {} and state["next_seq"] == 0
