"""Test-collection config: the kernel/model/AOT suites need jax (+ pallas)
and hypothesis; the golden-vector suite needs only numpy. Containers
without jax (including the `python-tests` CI job's minimal flavor) still
run the vector suite — jax-dependent modules are skipped at collection
instead of erroring on import.

Also puts this directory on sys.path so tests can `import gen_vectors`,
and the repo's `python/` dir so they can `from compile... import ...`.
"""

import importlib.util
import sys
from pathlib import Path

HERE = Path(__file__).resolve().parent
for p in (HERE, HERE.parent):
    if str(p) not in sys.path:
        sys.path.insert(0, str(p))


def _missing(mod):
    return importlib.util.find_spec(mod) is None


collect_ignore = []
if _missing("jax") or _missing("hypothesis"):
    collect_ignore += ["test_kernels.py", "test_model.py", "test_aot.py"]
