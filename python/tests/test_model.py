"""L2 model invariants on the micro config (fast enough for CI)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


CFG = M.CONFIGS["micro"]


@pytest.fixture(scope="module")
def params():
    return tuple(M.init_params(CFG, 0))


def _tokens(seed, batch=None, seq=None):
    rng = np.random.default_rng(seed)
    b = batch or CFG.eval_batch
    s = seq or CFG.seq_len
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, s)),
                       dtype=jnp.int32)


def test_param_specs_cover_init(params):
    specs = M.param_specs(CFG)
    assert len(specs) == len(params)
    for (name, shape), arr in zip(specs, params):
        assert tuple(arr.shape) == tuple(shape), name


def test_param_count_micro():
    n = sum(int(np.prod(s)) for _, s in M.param_specs(CFG))
    # micro: d=64, 2 blocks, dff=256, vocab=256, seq=32
    assert n == sum(int(np.prod(a.shape))
                    for a in M.init_params(CFG, 1))
    assert 100_000 < n < 1_000_000


def test_linear_registry_matches_specs():
    specs = dict(M.param_specs(CFG))
    regs = M.linear_registry(CFG)
    assert len(regs) == 6 * CFG.n_layers
    for reg in regs:
        assert specs[reg["param"]] == (reg["d"], reg["c"])
        assert reg["m"] == reg["d"] * reg["c"]


def test_fwd_loss_shape_and_range(params):
    nll = M.fwd_loss(CFG, params, _tokens(0))
    assert nll.shape == (CFG.eval_batch, CFG.seq_len - 1)
    # untrained byte-level model: near-uniform, loss ~ ln(256) = 5.55
    assert 4.0 < float(nll.mean()) < 8.0
    assert np.all(np.asarray(nll) >= 0.0)


def test_forward_is_causal(params):
    """Changing a future token must not change past losses."""
    t1 = _tokens(1)
    t2 = np.asarray(t1).copy()
    t2[:, -1] = (t2[:, -1] + 1) % CFG.vocab
    n1 = np.asarray(M.fwd_loss(CFG, params, t1))
    n2 = np.asarray(M.fwd_loss(CFG, params, jnp.asarray(t2)))
    # last position's loss may change (its target changed); earlier must not
    np.testing.assert_allclose(n1[:, :-1], n2[:, :-1], rtol=1e-5, atol=1e-5)


def test_fwd_logits_matches_forward(params):
    tok = _tokens(2)
    last = M.fwd_logits(CFG, params, tok)
    assert last.shape == (CFG.eval_batch, CFG.vocab)
    p = M.params_dict(CFG, list(params))
    full = M.forward(CFG, p, tok)
    np.testing.assert_allclose(last, full[:, -1, :], rtol=1e-5, atol=1e-5)


def test_calib_grads_shapes_and_positivity(params):
    tok = _tokens(3, batch=CFG.calib_batch)
    g, xn = M.calib_grads(CFG, params, tok)
    L = len(M.linear_registry(CFG))
    assert g.shape == (L,) and xn.shape == (L,)
    assert np.all(np.asarray(g) > 0)
    assert np.all(np.asarray(xn) > 0)


def test_calib_capture_shapes(params):
    tok = _tokens(4, batch=CFG.calib_batch)
    outs = M.calib_capture(CFG, params, tok)
    regs = M.linear_registry(CFG)
    # output 0 is the loss (keeps all params live in the lowered HLO)
    assert len(outs) == len(regs) + 1
    assert outs[0].shape == ()
    n = CFG.calib_batch * CFG.seq_len
    for cap, reg in zip(outs[1:], regs):
        assert cap.shape == (n, reg["d"]), reg["name"]


def test_calib_capture_consistent_with_xnorms(params):
    tok = _tokens(5, batch=CFG.calib_batch)
    outs = M.calib_capture(CFG, params, tok)
    _, xn = M.calib_grads(CFG, params, tok)
    want = np.array([float(jnp.linalg.norm(c)) for c in outs[1:]])
    np.testing.assert_allclose(np.asarray(xn), want, rtol=1e-4)


def test_dummy_injection_is_zero_at_eval(params):
    """Zero dummies must not change the forward pass."""
    tok = _tokens(6, batch=CFG.calib_batch)
    base = M.fwd_loss(CFG, params, tok)
    dm = M.make_dummies(CFG, CFG.calib_batch)
    p = M.params_dict(CFG, list(params))
    with_dm = M.token_losses(CFG, p, tok, dummies=dm)
    np.testing.assert_allclose(base, with_dm, rtol=1e-6, atol=1e-6)


def test_train_step_reduces_loss_on_repeated_batch(params):
    tok = _tokens(7, batch=CFG.train_batch)
    p = params
    m = tuple(jnp.zeros_like(a) for a in p)
    v = tuple(jnp.zeros_like(a) for a in p)
    losses = []
    for step in range(8):
        p, m, v, loss = M.train_step(
            CFG, p, m, v, jnp.asarray(step, jnp.int32),
            jnp.asarray(3e-3, jnp.float32), tok)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses


def test_train_step_preserves_shapes(params):
    tok = _tokens(8, batch=CFG.train_batch)
    m = tuple(jnp.zeros_like(a) for a in params)
    p2, m2, v2, _ = M.train_step(CFG, params, m, m,
                                 jnp.asarray(0, jnp.int32),
                                 jnp.asarray(1e-3, jnp.float32), tok)
    for a, b in zip(params, p2):
        assert a.shape == b.shape
    assert len(p2) == len(m2) == len(v2) == len(params)


def test_init_is_deterministic():
    a = M.init_params(CFG, 42)
    b = M.init_params(CFG, 42)
    c = M.init_params(CFG, 43)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))
