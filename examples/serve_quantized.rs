//! Serving example: batched token generation over RaanA-quantized weights.
//!
//! Demonstrates the L3 request path (DESIGN.md): a batching server drains a
//! request queue into fixed-shape `fwd_logits` executions — continuous
//! batching over the model's context window — and reports latency
//! percentiles, throughput, and batch occupancy.
//!
//! ```sh
//! ./target/release/examples/serve_quantized [--model micro] [--requests 24]
//! ```

use anyhow::Result;
use raana::calib::CalibMode;
use raana::cli::Args;
use raana::data::{detokenize, tokenize};
use raana::experiments::{raana_quantize, Env};
use raana::model::artifacts_root;
use raana::quant::TrickConfig;
use raana::runtime::{ModelRuntime, Runtime};
use raana::serve::Server;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.opt_or("model", "micro").to_string();
    let n_req = args.opt_usize("requests", 24)?;
    let new_tokens = args.opt_usize("tokens", 12)?;
    let avg_bits = args.opt_f64("avg-bits", 4.1)?;

    let env = Env::load(&model)?;
    let (qparams, report) = raana_quantize(
        &env,
        &CalibMode::FewShot(5),
        avg_bits,
        &(1..=8).collect::<Vec<u8>>(),
        &TrickConfig::default(),
        11,
        0,
    )?;
    println!(
        "serving '{model}' quantized to {:.2} avg bits ({} linear layers)",
        report.avg_bits,
        report.layers.len()
    );
    let batch = env.mrt.manifest.eval_batch;
    drop(env); // the server thread builds its own (non-Send) runtime

    let m2 = model.clone();
    let server = Server::start(
        move || {
            let rt = Runtime::cpu()?;
            ModelRuntime::load(&rt, &artifacts_root(), &m2)
        },
        qparams,
    );

    // fan in a burst of prompts from multiple submitter threads
    let prompts: Vec<String> = (0..n_req)
        .map(|i| format!("The {i} curious fox leaped over the "))
        .collect();
    let mut rxs = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (id, rx) = server.submit(tokenize(p), new_tokens, 0.8, i as u64)?;
        rxs.push((id, rx));
    }
    for (id, rx) in rxs {
        let c = rx.recv()?;
        println!(
            "  req {id:>3}  {:>6.1} ms  {:?}",
            c.latency_secs * 1e3,
            detokenize(&c.tokens)
        );
    }
    let stats = server.shutdown()?;
    println!(
        "throughput {:.1} tok/s | occupancy {:.2} | p50 {:.0} ms | p95 {:.0} ms | {} batch steps",
        stats.throughput_tok_s(),
        stats.mean_batch_occupancy(batch),
        stats.p50_latency() * 1e3,
        stats.p95_latency() * 1e3,
        stats.batch_steps
    );
    Ok(())
}
