//! Calibration-efficiency study (paper §4.2's core claim): RaanA's
//! sensitivities α_k are stable under tiny calibration sets — unlike
//! Hessian-based methods that need thousands of samples.
//!
//! Prints the α_k correlation between few-shot sizes (1, 2, 5, 10) and the
//! zero-shot synthetic sentence, plus the resulting bit allocations.
//!
//! ```sh
//! ./target/release/examples/calibration_study [--model micro]
//! ```

use anyhow::Result;
use raana::allocate::AllocProblem;
use raana::calib::{calibrate, CalibMode};
use raana::cli::Args;
use raana::experiments::Env;

fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let cov: f64 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
    let va: f64 = a.iter().map(|x| (x - ma) * (x - ma)).sum();
    let vb: f64 = b.iter().map(|y| (y - mb) * (y - mb)).sum();
    cov / (va.sqrt() * vb.sqrt()).max(1e-30)
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.opt_or("model", "micro");
    let env = Env::load(model)?;
    let m = &env.mrt.manifest;

    let modes = [
        ("zero", CalibMode::ZeroShot),
        ("few:1", CalibMode::FewShot(1)),
        ("few:2", CalibMode::FewShot(2)),
        ("few:5", CalibMode::FewShot(5)),
        ("few:10", CalibMode::FewShot(10)),
    ];
    let mut alphas = Vec::new();
    for (name, mode) in &modes {
        let c = calibrate(&env.mrt, &env.params, mode, &env.wiki)?;
        println!(
            "{name:>7}: alpha range [{:.3e}, {:.3e}]",
            c.alphas.iter().cloned().fold(f64::INFINITY, f64::min),
            c.alphas.iter().cloned().fold(0.0, f64::max)
        );
        alphas.push((name.to_string(), c.alphas));
    }

    // correlation vs the largest few-shot run (the "truth" proxy)
    let truth = &alphas.last().unwrap().1;
    println!("\nalpha correlation vs few:10 (paper: stable under tiny n_c):");
    for (name, a) in &alphas {
        println!("  {name:>7}: pearson r = {:.4}", pearson(a, truth));
    }

    // resulting allocations at 3.1 target bits
    println!("\nbit allocations at 3.1 avg bits:");
    let ms: Vec<usize> = m.linears.iter().map(|l| l.m).collect();
    for (name, a) in &alphas {
        let p = AllocProblem {
            alphas: a.clone(),
            m: ms.clone(),
            bit_choices: (1..=8).collect(),
            budget: AllocProblem::budget_for_avg_bits(&ms, 3.0),
        };
        let sol = p.solve()?;
        println!("  {name:>7}: {:?}", sol.bits);
    }
    Ok(())
}
