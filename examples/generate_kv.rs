//! KV-cached incremental decoding, artifact-free: quantize a demo model
//! to packed RaBitQ codes, prefill a prompt once, then generate one token
//! per `decode_step` — and verify against the full-recompute reference.
//!
//! ```sh
//! ./target/release/examples/generate_kv [--tokens 48] [--bits 4] \
//!     [--prompt "the quick brown fox "] [--check]
//! ```
//!
//! `--check` recomputes every step's logits from scratch and asserts the
//! two paths are bit-identical (the ISSUE 2 acceptance property, live).

use std::time::Instant;

use anyhow::Result;
use raana::cli::Args;
use raana::data::{detokenize, tokenize};
use raana::experiments::native_demo_packed;
use raana::runtime::ModelRuntime;

fn argmax(logits: &[f32]) -> i32 {
    raana::util::argmax(logits) as i32
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let new_tokens = args.opt_usize("tokens", 48)?;
    let bits_raw = args.opt_usize("bits", 4)?;
    anyhow::ensure!((1..=8).contains(&bits_raw), "--bits must be in 1..=8, got {bits_raw}");
    let bits = bits_raw as u8;
    let prompt_text = args.opt_or("prompt", "the quick brown fox ").to_string();
    let check = args.flag("check");

    let (manifest, params, packed) = native_demo_packed("generate-kv", 256, 4, bits, 11)?;
    println!(
        "demo model: d={} layers={} seq_len={} | {} linears packed at {bits} bits \
         (avg {:.2} incl. side payloads)",
        manifest.d_model,
        manifest.n_layers,
        manifest.seq_len,
        packed.layers.len(),
        packed.avg_bits()
    );
    let seq = manifest.seq_len;
    let mut mrt = ModelRuntime::native(manifest)?;
    mrt.attach_packed(packed)?;

    let mut cache = mrt.new_kv_cache(1);
    println!(
        "kv cache: 1 slot x {} positions x {} layers ({} KiB resident)",
        cache.capacity(),
        mrt.manifest.n_layers,
        cache.mem_bytes() / 1024
    );

    let mut ctx = tokenize(&prompt_text);
    if ctx.len() > seq {
        ctx.drain(..ctx.len() - seq);
    }
    let t0 = Instant::now();
    let mut logits = mrt.prefill(&params, &mut cache, 0, &ctx)?;
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t1 = Instant::now();
    let mut generated = Vec::with_capacity(new_tokens);
    for _ in 0..new_tokens {
        if check {
            // `logits` belong to the current (truncated) context — they
            // must match a from-scratch forward bit-for-bit
            let lo = ctx.len().saturating_sub(seq);
            let want = mrt.last_logits_ctx(&params, &ctx[lo..])?;
            assert_eq!(logits, want, "KV logits must equal full recompute");
        }
        let tok = argmax(&logits);
        generated.push(tok);
        ctx.push(tok);
        if cache.is_full(0) {
            // window slide: absolute positions shift, so re-prefill
            let lo = ctx.len().saturating_sub(seq);
            logits = mrt.prefill(&params, &mut cache, 0, &ctx[lo..])?;
        } else {
            logits = mrt.decode_step(&params, &mut cache, &[0], &[tok])?;
        }
    }
    let decode_secs = t1.elapsed().as_secs_f64();

    println!(
        "prefill {} tokens in {prefill_ms:.1} ms; generated {new_tokens} tokens \
         at {:.1} tok/s{}",
        ctx.len() - new_tokens,
        new_tokens as f64 / decode_secs,
        if check { " (bit-exactness checked every step)" } else { "" }
    );
    println!("---\n{}{}", prompt_text, detokenize(&generated).escape_debug());
    Ok(())
}
