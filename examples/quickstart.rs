//! Quickstart: quantize a trained model with RaanA and measure perplexity.
//!
//! ```sh
//! make artifacts && cargo build --release --offline
//! ./target/release/examples/quickstart [--model micro] [--avg-bits 3.1]
//! ```
//!
//! Uses (or trains, on first run) the checkpoint under artifacts/<model>/.

use anyhow::Result;
use raana::calib::CalibMode;
use raana::cli::Args;
use raana::experiments::{raana_quantize, Env};
use raana::quant::TrickConfig;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.opt_or("model", "micro");
    let avg_bits = args.opt_f64("avg-bits", 3.1)?;

    // 1. environment: AOT artifacts + corpora + trained weights
    let env = Env::load(model)?;
    println!(
        "model '{model}': {} params, {} quantizable linear layers",
        env.mrt.manifest.total_params(),
        env.mrt.manifest.linears.len()
    );

    // 2. the RaanA pipeline (paper Alg. 1): few-shot calibration (5
    //    sequences), AllocateBits DP, RaBitQ-H per layer
    let (qparams, report) = raana_quantize(
        &env,
        &CalibMode::FewShot(5),
        avg_bits,
        &(1..=8).collect::<Vec<u8>>(),
        &TrickConfig::default(),
        /*seed=*/ 42,
        /*threads=*/ 0,
    )?;
    println!(
        "quantized to {:.3} avg bits (calib {:.2}s, alloc {:.3}s, quant {:.2}s)",
        report.avg_bits, report.secs.0, report.secs.1, report.secs.2
    );
    println!(
        "bit allocation: {:?}",
        report.layers.iter().map(|l| l.bits).collect::<Vec<_>>()
    );

    // 3. evaluate both models on the synthwiki test split
    let ppl_fp = env.perplexity(&env.params, &env.wiki, 16)?;
    let ppl_q = env.perplexity(&qparams, &env.wiki, 16)?;
    println!("perplexity: fp32 {ppl_fp:.3} -> RaanA@{avg_bits} {ppl_q:.3}");
    Ok(())
}
