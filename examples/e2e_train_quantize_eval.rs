//! End-to-end validation driver (DESIGN.md §End-to-end validation):
//! train the tiny transformer on synthwiki via the AOT `train_step`
//! artifact, log the loss curve, run few-shot calibration, AllocateBits,
//! RaBitQ-H at several average bit-widths, evaluate perplexity against the
//! f32 reference, and cross-check the Rust dequant path against the Pallas
//! `qmatmul` artifact. Results for the recorded run live in EXPERIMENTS.md.
//!
//! ```sh
//! make e2e      # or ./target/release/examples/e2e_train_quantize_eval
//! ```

use anyhow::Result;
use raana::calib::CalibMode;
use raana::cli::Args;
use raana::experiments::{raana_quantize, Env};
use raana::model::artifacts_root;
use raana::quant::TrickConfig;
use raana::rabitq::{QuantizedMatrix, ScaleMode};
use raana::rng::Rng;
use raana::runtime::{lit_f32, to_vec_f32, Runtime};
use raana::tensor::Matrix;
use raana::util::Timer;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = args.opt_or("model", "tiny");
    let timer = Timer::start();

    // ------------------------------------------------ 1. train (or load)
    // Env::load trains via the train_step artifact when no checkpoint
    // exists and logs the loss curve (see EXPERIMENTS.md §E2E).
    let env = Env::load(model)?;
    let ppl_fp = env.perplexity(&env.params, &env.wiki, 32)?;
    println!("[e2e] fp32 reference ppl(synthwiki) = {ppl_fp:.3}");

    // ------------------------------------- 2. quantize at several widths
    for &target in &[2.1, 3.1, 4.1] {
        let (qparams, report) = raana_quantize(
            &env,
            &CalibMode::FewShot(5),
            target,
            &(1..=8).collect::<Vec<u8>>(),
            &TrickConfig::default(),
            7,
            0,
        )?;
        let ppl_q = env.perplexity(&qparams, &env.wiki, 32)?;
        println!(
            "[e2e] RaanA@{target}: actual {:.3} avg bits, ppl {:.3} \
             (x{:.3} vs fp32), quant {:.2}s",
            report.avg_bits,
            ppl_q,
            ppl_q / ppl_fp,
            report.secs.2
        );
    }

    // --------------------- 3. cross-check Rust dequant vs Pallas qmatmul
    // The kernels/qmatmul artifact implements paper Alg. 3 on the L1
    // Pallas path; the Rust QuantizedMatrix implements it natively. Both
    // must agree to float tolerance on the same codes.
    let (n, d, c, bits) = (128usize, 256usize, 256usize, 4u8);
    let rt = Runtime::cpu()?;
    let art = rt.load(&artifacts_root().join("kernels").join(format!(
        "qmatmul_{n}x{d}x{c}_b{bits}.hlo.txt"
    )))?;
    let mut rng = Rng::new(3);
    let v = Matrix::from_vec(d, c, rng.gaussian_vec(d * c));
    let x = Matrix::from_vec(n, d, rng.gaussian_vec(n * d));
    // MaxAbs mode matches the Pallas kernel's (search-free) scale choice.
    let qm = QuantizedMatrix::quantize(&v, bits, ScaleMode::MaxAbs, 0);
    let rust_est = qm.matmul_est(&x);

    let codes_f32: Vec<f32> = {
        // column-major codes -> row-major (d, c) array for the artifact
        let unpacked = qm.codes.unpack();
        let mut out = vec![0f32; d * c];
        for j in 0..c {
            for i in 0..d {
                out[i * c + j] = unpacked[j * d + i] as f32;
            }
        }
        out
    };
    let outs = art.run(&[
        lit_f32(&x.data, &[n, d])?,
        lit_f32(&codes_f32, &[d, c])?,
        lit_f32(&qm.r, &[c])?,
    ])?;
    let pallas_est = Matrix::from_vec(n, c, to_vec_f32(&outs[0])?);
    let rel = pallas_est.rel_err(&rust_est);
    println!("[e2e] qmatmul cross-check (Rust vs Pallas artifact): rel err {rel:.2e}");
    anyhow::ensure!(rel < 1e-4, "qmatmul paths disagree: {rel}");

    println!("[e2e] done in {:.1}s", timer.secs());
    Ok(())
}
