//! Minimal client for the HTTP serving front-end: submit a prompt to a
//! running `raana serve --http <port>` instance and print the tokens —
//! streamed live (chunk by chunk) or as one completion.
//!
//! ```sh
//! # terminal 1: the server (demo model, no artifacts needed)
//! ./target/release/raana serve --http 8080
//! # terminal 2:
//! ./target/release/examples/http_client --addr 127.0.0.1:8080 \
//!     --prompt "the quick brown fox " --tokens 24 --stream
//! ```
//!
//! Also a quick smoke check of the other endpoints: `--stats` fetches
//! `/v1/stats`, `--health` fetches `/healthz`.

use anyhow::{bail, Result};
use raana::cli::Args;
use raana::data::{detokenize, tokenize};
use raana::json;
use raana::net::http_request;

fn main() -> Result<()> {
    let args = Args::from_env();
    let addr = args.opt_or("addr", "127.0.0.1:8080").to_string();

    if args.flag("health") {
        let r = http_request(&addr, "GET", "/healthz", None)?;
        println!("{} {}", r.status, r.body_str()?);
        return Ok(());
    }
    if args.flag("stats") {
        let r = http_request(&addr, "GET", "/v1/stats", None)?;
        println!("{} {}", r.status, r.body_str()?);
        return Ok(());
    }

    let prompt_text = args.opt_or("prompt", "the quick brown fox ").to_string();
    let tokens = args.opt_usize("tokens", 24)?;
    let temperature = args.opt_f64("temperature", 0.0)?;
    let seed = args.opt_u64("seed", 0)?;
    let stream = args.flag("stream");

    let prompt = tokenize(&prompt_text);
    let body = json::obj(vec![
        ("prompt", json::arr(prompt.iter().map(|&t| json::num(t as f64)).collect())),
        ("max_new_tokens", json::num(tokens as f64)),
        ("temperature", json::num(temperature)),
        ("seed", json::num(seed as f64)),
        ("stream", json::Value::Bool(stream)),
    ])
    .to_json();

    let resp = http_request(&addr, "POST", "/v1/generate", Some(&body))?;
    if resp.status != 200 {
        bail!("server answered {}: {}", resp.status, resp.body_str().unwrap_or("<binary>"));
    }

    if stream {
        // one chunk per event: token lines, then the final done object
        let mut toks: Vec<i32> = Vec::new();
        for chunk in &resp.chunks {
            let line = std::str::from_utf8(chunk)?;
            let v = json::parse(line.trim_end())?;
            if v.get("done").is_some() {
                println!(
                    "done: {} tokens in {:.1} ms",
                    v.get("tokens").and_then(|t| t.as_arr()).map(|a| a.len()).unwrap_or(0),
                    v.get("latency_secs").and_then(|x| x.as_f64()).unwrap_or(0.0) * 1e3
                );
            } else if let Some(t) = v.get("token").and_then(|x| x.as_f64()) {
                toks.push(t as i32);
            }
        }
        println!("---\n{}{}", prompt_text, detokenize(&toks).escape_debug());
    } else {
        let v = resp.json()?;
        let toks: Vec<i32> = v
            .req("tokens")?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|f| f as i32)
            .collect();
        println!(
            "request {} finished in {:.1} ms ({} steps)",
            v.req_usize("id")?,
            v.req("latency_secs")?.as_f64().unwrap_or(0.0) * 1e3,
            v.req_usize("steps")?
        );
        println!("---\n{}{}", prompt_text, detokenize(&toks).escape_debug());
    }
    Ok(())
}
