//! Offline vendor shim for the `anyhow` crate: the exact API subset this
//! workspace uses (`Error`, `Result`, `Context`, `anyhow!`, `bail!`,
//! `ensure!`), implemented without any dependencies.
//!
//! Differences from upstream are deliberate simplifications: the error
//! chain is stored as rendered strings (no backtraces, no downcasting of
//! sources), and `Error` implements `std::error::Error` directly so one
//! blanket `Context` impl covers both std errors and `anyhow::Result`.

use std::any::{Any, TypeId};
use std::fmt::{self, Debug, Display};

/// Error type: an outermost message plus a rendered cause chain.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an additional layer of context (new outermost message).
    pub fn context<C: Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The rendered cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl std::error::Error for Error {}

/// Convert any std error into `Error`, preserving an existing `Error`'s
/// chain when the source already is one (checked via `TypeId`).
fn into_error<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
    if TypeId::of::<E>() == TypeId::of::<Error>() {
        let boxed: Box<dyn Any> = Box::new(e);
        return *boxed.downcast::<Error>().expect("TypeId checked");
    }
    let mut chain = vec![e.to_string()];
    let mut src = e.source();
    while let Some(s) = src {
        chain.push(s.to_string());
        src = s.source();
    }
    Error { chain }
}

macro_rules! impl_from {
    ($($ty:ty),* $(,)?) => {
        $(impl From<$ty> for Error {
            fn from(e: $ty) -> Error {
                into_error(e)
            }
        })*
    };
}

impl_from!(
    std::io::Error,
    std::str::Utf8Error,
    std::string::FromUtf8Error,
    std::num::ParseIntError,
    std::num::ParseFloatError,
    std::num::TryFromIntError,
    std::char::ParseCharError,
    std::fmt::Error,
    std::env::VarError,
    std::time::SystemTimeError,
    std::sync::mpsc::RecvError,
    std::sync::mpsc::RecvTimeoutError,
    std::sync::mpsc::TryRecvError,
    std::array::TryFromSliceError,
);

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, turning them into `anyhow::Result`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| into_error(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| into_error(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/xyz")?;
        Ok(())
    }

    #[test]
    fn from_io_and_context_chain() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn context_on_anyhow_result_preserves_chain() {
        let inner: Result<()> = Err(anyhow!("inner"));
        let e = inner.context("middle").context("outer").unwrap_err();
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "middle", "inner"]);
        assert_eq!(e.root_cause(), "inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(1).context("missing").unwrap(), 1);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
    }
}
