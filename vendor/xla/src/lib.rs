//! Offline vendor stub for the `xla` (xla_extension) crate.
//!
//! The build environment has no XLA/PJRT shared library, so this stub
//! keeps the L3 coordinator compiling against the same interface while
//! reporting the PJRT backend as unavailable at runtime:
//!
//! * [`Literal`] is fully functional (host-side tensor container) — the
//!   literal glue in `raana::runtime` and its unit tests work unchanged.
//! * [`PjRtClient::cpu`] returns an error; every code path that would
//!   execute a compiled HLO artifact therefore falls back (or errors)
//!   cleanly, and the native CPU backend serves in its place.
//!
//! When the real `xla_extension` 0.5.1 is restored in the vendor set, this
//! path dependency can be swapped back without touching the coordinator.

use std::fmt;

/// XLA error type (mirrors the real crate's string-carrying error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT backend unavailable: the offline vendor set \
     ships an interface stub (no libxla_extension). Use the native CPU \
     backend (raana::runtime::ModelRuntime::native) instead.";

// ------------------------------------------------------------------ literal

/// Element types a [`Literal`] can hold.
#[derive(Clone, Debug, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor literal (the only fully functional part of the stub).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

/// Sealed-ish conversion trait for supported element types.
pub trait NativeType: Copy {
    fn store(v: Vec<Self>) -> LiteralData;
    fn extract(d: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn store(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn extract(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn store(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn extract(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        Literal { data: T::store(data.to_vec()), dims }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::store(vec![v]), dims: Vec::new() }
    }

    /// Tuple literal (what `return_tuple=True` entry points produce).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { data: LiteralData::Tuple(parts), dims: Vec::new() }
    }

    fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(_) => 0,
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape: {} elements into shape {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Flattened element vector (type must match the stored one).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data).ok_or_else(|| Error::new("literal element type mismatch"))
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            LiteralData::Tuple(parts) => Ok(parts),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

// --------------------------------------------------------------- PJRT stubs

/// PJRT client handle. `cpu()` always fails in the stub.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(UNAVAILABLE))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Parsed HLO module proto. Parsing requires the real library.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(UNAVAILABLE))
    }
}

/// Compiled executable. Cannot be constructed by the stub.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(UNAVAILABLE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(1.5f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(s.clone().to_tuple().is_err());
    }

    #[test]
    fn pjrt_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
